"""Scan-over-rounds engine: equivalence with the per-round loop.

The contract under test: engine="scan" is *bitwise* identical to
engine="loop" at fixed seed — same losses, same p_hats, same privacy spend,
same hard privacy stop — while dispatching chunk_rounds rounds per device
call. Chunk boundaries are deliberately chosen NOT to divide the horizon so
partial chunks are exercised.
"""
import numpy as np
import pytest

import jax

from repro.channel import RayleighFading
from repro.checkpoint import checkpoint as ckpt
from repro.core import dp, engine as eng, fedsim, pairzero
from repro.core import power_control as pc
from repro.models import registry


# ---------------------------------------------------------------------------
# Control-trace precomputation == per-round make_control
# ---------------------------------------------------------------------------

def test_control_trace_matches_make_control(make_pz):
    pz = make_pz(scheme="solution", rounds=16)
    h = RayleighFading().realize(pz.seed ^ 0xC4A7, 16, pz.n_clients).h
    sched = pc.make_schedule(
        "analog", "solution", h, power=100.0, n0=1.0, gamma=5.0,
        n_clients=pz.n_clients, e0=pz.power.e0,
        contraction_a=pz.power.contraction_a,
        contraction_a_tilde=pz.power.contraction_a_tilde,
        epsilon=5.0, delta=0.01)
    trace = eng.build_trace(sched, pz, 3, 16)
    for t in range(3, 16):
        ctl = pairzero.make_control(t, sched, pz.seed, pz.n_clients)
        for key in ctl:
            np.testing.assert_array_equal(
                np.asarray(ctl[key]), np.asarray(trace.ctl[key][t - 3]),
                err_msg=f"round {t} field {key}")


def test_fault_trace_replays_loop_order(make_pz):
    """Chunked trace building consumes the stateful FaultModel RNG in the
    same order the per-round loop does."""
    from repro.runtime.fault import FaultModel, combined_mask
    pz = make_pz(rounds=10, scheme="perfect")
    sched = pc.PowerSchedule(c=np.ones(10), sigma=np.zeros((10, 5)),
                             scheme="perfect", n0=0.0)
    fm_loop = FaultModel(5, dropout_p=0.3, straggler_p=0.1, seed=7)
    loop_masks = [combined_mask(t, fm_loop, None, n_clients=5)
                  for t in range(10)]
    fm_scan = FaultModel(5, dropout_p=0.3, straggler_p=0.1, seed=7)
    tr_a = eng.build_trace(sched, pz, 0, 6, fault=fm_scan)
    tr_b = eng.build_trace(sched, pz, 6, 10, fault=fm_scan)
    scan_masks = np.concatenate([np.asarray(tr_a.ctl["mask"]),
                                 np.asarray(tr_b.ctl["mask"])])
    np.testing.assert_array_equal(np.stack(loop_masks), scan_masks)


def test_chunk_boundaries_align_to_cadences():
    # plain chunking
    assert eng.chunk_boundaries(0, 10, 4) == [(0, 4), (4, 8), (8, 10)]
    # eval every 5 forces a cut at 5 even though the chunk would span it
    assert eng.chunk_boundaries(0, 12, 8, (5,)) == \
        [(0, 5), (5, 10), (10, 12)]
    # resume from mid-cadence: first cut lands back on the cadence grid
    assert eng.chunk_boundaries(3, 12, 8, (5,)) == [(3, 5), (5, 10), (10, 12)]
    # degenerate chunk size still advances
    assert eng.chunk_boundaries(0, 3, 0) == [(0, 1), (1, 2), (2, 3)]


# ---------------------------------------------------------------------------
# Bitwise scan == loop (the acceptance-criterion test)
# ---------------------------------------------------------------------------

def test_scan_bitwise_identical_to_loop_opt125m(opt125m_reduced, make_pz,
                                                make_pipeline):
    """8 rounds of the paper's architecture (reduced): identical trajectory
    bit for bit, across uneven chunk boundaries (3+3+2)."""
    cfg = opt125m_reduced
    pz = make_pz(scheme="solution", n_perturb=1, rounds=8)
    pipe = lambda: make_pipeline(vocab=cfg.vocab_size, seq=32, batch=4)
    res_loop = fedsim.run(cfg, pz, pipe(), rounds=8, engine="loop")
    res_scan = fedsim.run(cfg, pz, pipe(), rounds=8, engine="scan",
                          chunk_rounds=3)
    assert res_scan.losses == res_loop.losses          # bitwise, not allclose
    assert res_scan.p_hats == res_loop.p_hats
    assert res_scan.privacy_spent == res_loop.privacy_spent
    assert len(res_scan.losses) == 8


def test_scan_matches_loop_fo_variant(tiny_model, make_pz, make_pipeline):
    """FO baseline under scan: fp-tolerance equivalence only — XLA fuses
    value_and_grad differently inside the scan body (see fedsim.run
    docstring). Bit-identity is guaranteed for the ZO variants only."""
    pz = make_pz(variant="fo", scheme="perfect", lr=3e-3, rounds=6)
    pipe = lambda: make_pipeline()
    res_loop = fedsim.run(tiny_model, pz, pipe(), rounds=6, engine="loop")
    res_scan = fedsim.run(tiny_model, pz, pipe(), rounds=6, engine="scan",
                          chunk_rounds=4)
    np.testing.assert_allclose(res_scan.losses, res_loop.losses,
                               rtol=1e-5, atol=1e-5)


def test_scan_matches_loop_sign_variant(tiny_model, make_pz, make_pipeline):
    pz = make_pz(variant="sign", scheme="solution", lr=2e-2, rounds=6)
    pipe = lambda: make_pipeline()
    res_loop = fedsim.run(tiny_model, pz, pipe(), rounds=6, engine="loop")
    res_scan = fedsim.run(tiny_model, pz, pipe(), rounds=6, engine="scan",
                          chunk_rounds=4)
    assert res_scan.losses == res_loop.losses


def test_scan_metrics_and_on_round(tiny_model, make_pz, make_pipeline):
    """on_round fires once per round with per-round (not stacked) metrics."""
    pz = make_pz(scheme="perfect", rounds=5)
    seen = []
    fedsim.run(tiny_model, pz, make_pipeline(), rounds=5, engine="scan",
               chunk_rounds=2,
               on_round=lambda t, m: seen.append((t, m["p_clients"].shape)))
    assert [t for t, _ in seen] == [0, 1, 2, 3, 4]
    assert all(shape == (5,) for _, shape in seen)


# ---------------------------------------------------------------------------
# Vectorized DP lookahead / batched spend == reference per-round loop
# ---------------------------------------------------------------------------

def _reference_affordable(spent, budget, costs, slack=1e-6):
    """The historical per-round float loop, kept verbatim as the oracle."""
    for r in range(len(costs)):
        cost = float(costs[r])
        if spent + cost > budget * (1.0 + slack):
            return r
        spent += cost
    return len(costs)


def test_affordable_rounds_pins_reference_loop():
    """The cumsum lookahead trips on the bit-identical round as the
    per-round loop, including adversarial near-budget cost vectors."""
    rng = np.random.default_rng(7)
    acct = dp.PrivacyAccountant(5.0, 0.01)
    budget = acct.budget
    for trial in range(200):
        n = int(rng.integers(1, 40))
        costs = rng.uniform(0, budget / max(4, n // 2), size=n)
        if trial % 3 == 0:
            # exact-boundary adversary: make a prefix sum to ~the budget
            k = int(rng.integers(1, n + 1))
            costs[:k] *= budget / max(costs[:k].sum(), 1e-30)
        spent = float(rng.uniform(0, budget))
        acct.spent = spent
        trace = eng.ControlTrace(
            t0=0, ctl={"seed": np.zeros(n, np.uint32)}, acct_cost=costs,
            charged=True)
        assert eng.affordable_rounds(acct, trace) == \
            _reference_affordable(spent, budget, costs), \
            f"trial {trial}: vectorized lookahead diverged from the loop"


def test_charge_rounds_batched_spend_bitwise():
    """spend_batch advances the ledger by the same float64 left fold as
    per-round spend — final spent is bit-identical, history intact."""
    rng = np.random.default_rng(3)
    costs = rng.uniform(0, 0.1, size=23)
    a = dp.PrivacyAccountant(5.0, 0.01, spent=0.123456789)
    b = dp.PrivacyAccountant(5.0, 0.01, spent=0.123456789)
    for c in costs:
        a.spend(float(c))
    b.spend_batch(costs)
    assert a.spent == b.spent                      # bitwise, not approx
    assert len(b.history) == len(a.history)
    trace = eng.ControlTrace(t0=0, ctl={}, acct_cost=costs, charged=True)
    c2 = dp.PrivacyAccountant(5.0, 0.01, spent=0.123456789)
    eng.charge_rounds(c2, trace, 23)
    assert c2.spent == a.spent


# ---------------------------------------------------------------------------
# Batch staging + chunk prefetch
# ---------------------------------------------------------------------------

def test_batch_stager_reuses_buffers_and_matches_pipeline(make_pipeline):
    """Each staged chunk matches pipeline.batch exactly. Staged arrays are
    valid until their slot is rewritten (device_put may zero-copy alias the
    host buffer on CPU), so each chunk is verified before the next reuse —
    the same lifetime the driver guarantees via ChunkPrefetcher.kick."""
    pipe = make_pipeline()
    stager = eng.BatchStager(pipe, slots=2)
    hosts = []
    for a in (0, 4, 8):                           # slots 0, 1, 0
        dev = stager.stage(a, a + 4)
        for r in range(4):
            want = pipe.batch(a + r)
            for k in dev:
                np.testing.assert_array_equal(np.asarray(dev[k][r]), want[k])
        hosts.append({k: np.asarray(v).copy() for k, v in dev.items()})
    # slot 0's host buffers were reused for the third chunk (no realloc);
    # the one-shot wrapper agrees with the staged values
    one = eng.stack_batches(pipe, 4, 8)
    for k in one:
        np.testing.assert_array_equal(np.asarray(one[k]), hosts[1][k])


def test_chunk_prefetcher_kick_get_contract():
    seen = []

    def prepare(a, b):
        seen.append((a, b))
        return (a, b)

    bounds = [(0, 3), (3, 6), (6, 8)]
    pf = eng.ChunkPrefetcher(prepare, bounds, overlap=True)
    try:
        assert pf.get(0) == (0, 3)                # nothing kicked: inline
        pf.kick(1)
        pf.kick(1)                                # double-kick is a no-op
        assert pf.get(1) == (3, 6)
        assert pf.get(2) == (6, 8)                # never kicked: inline
        assert seen == bounds                     # round order preserved
        assert pf.stall_s >= 0.0
        with pytest.raises(AssertionError):
            pf.get(1)                             # out-of-order consumption
    finally:
        pf.close()


def test_chunk_prefetcher_kick_out_of_order_ignored():
    pf = eng.ChunkPrefetcher(lambda a, b: (a, b), [(0, 2), (2, 4)],
                             overlap=True)
    try:
        pf.kick(1)                                # not next: ignored
        assert pf.get(0) == (0, 2)
        assert pf.get(1) == (2, 4)
    finally:
        pf.close()


def test_scan_overlap_off_bitwise(tiny_model, make_pz, make_pipeline):
    """The prefetch thread is pure pipelining — overlap off/on and the
    no-overlap control produce the identical trajectory."""
    pz = make_pz(scheme="solution", rounds=7)
    pipe = lambda: make_pipeline()
    on = fedsim.run(tiny_model, pz, pipe(), rounds=7, engine="scan",
                    chunk_rounds=3)
    off = fedsim.run(tiny_model, pz, pipe(), rounds=7, engine="scan",
                     chunk_rounds=3, overlap=False)
    assert on.losses == off.losses
    assert on.p_hats == off.p_hats
    assert on.prep_stall_s >= 0.0 and off.prep_stall_s >= 0.0


def test_run_result_declares_params(tiny_model, make_pz, make_pipeline):
    """RunResult.params is a first-class field (no attribute smuggling)."""
    import dataclasses
    assert "params" in {f.name for f in dataclasses.fields(fedsim.RunResult)}
    res = fedsim.run(tiny_model, make_pz(rounds=2), make_pipeline(),
                     rounds=2, engine="scan", chunk_rounds=2)
    assert res.params is not None
    import jax.numpy as jnp
    assert all(isinstance(leaf, jnp.ndarray)
               for leaf in jax.tree_util.tree_leaves(res.params))


# ---------------------------------------------------------------------------
# Checkpoint/resume across chunk boundaries
# ---------------------------------------------------------------------------

def test_scan_checkpoint_resume_equivalence(tiny_model, make_pz,
                                            make_pipeline, tmp_path):
    """Interrupt a scan run at a chunk-interior checkpoint cadence, resume
    with a different chunking — the tail must match the uninterrupted loop
    run bitwise."""
    pz = make_pz(scheme="solution", rounds=8)
    pipe = lambda: make_pipeline()
    res_ref = fedsim.run(tiny_model, pz, pipe(), rounds=8, engine="loop")

    ck = str(tmp_path / "ck")
    fedsim.run(tiny_model, pz, pipe(), rounds=4, engine="scan",
               chunk_rounds=3, checkpoint_dir=ck, checkpoint_every=4)
    res_res = fedsim.run(tiny_model, pz, pipe(), rounds=8, engine="scan",
                         chunk_rounds=3, checkpoint_dir=ck,
                         checkpoint_every=1000)
    assert res_res.resumed_from == 4
    assert res_res.losses == res_ref.losses[4:]
    # and the DP ledger picked up where the interrupted run left it
    assert res_res.privacy_spent == pytest.approx(res_ref.privacy_spent)


# ---------------------------------------------------------------------------
# Hard privacy stop, mid-chunk
# ---------------------------------------------------------------------------

def _near_exhausted_checkpoint(cfg, pz, ckdir, start_round, affordable):
    """Write a checkpoint whose accountant affords exactly `affordable` more
    rounds of pz's schedule past `start_round` — the next chunk must trip
    mid-flight."""
    horizon = pz.rounds
    h = RayleighFading().realize(pz.seed ^ 0xC4A7, horizon,
                                 pz.n_clients).h
    sched = pc.make_schedule(
        pz.variant, pz.power.scheme, h, power=pz.channel.power,
        n0=pz.channel.n0, gamma=pz.zo.clip_gamma, n_clients=pz.n_clients,
        e0=pz.power.e0, contraction_a=pz.power.contraction_a,
        contraction_a_tilde=pz.power.contraction_a_tilde,
        epsilon=pz.dp.epsilon, delta=pz.dp.delta)
    budget = dp.r_dp(pz.dp.epsilon, pz.dp.delta)
    costs = [dp.round_privacy_cost(float(sched.c[t]), pz.zo.clip_gamma,
                                   sched.effective_noise_std(t))
             for t in range(start_round, start_round + affordable + 1)]
    # afford the first `affordable` rounds but not the one after
    spent = budget - sum(costs[:affordable]) - 0.5 * costs[affordable]
    import jax.numpy as jnp
    params = registry.init_params(jax.random.key(pz.seed), cfg, jnp.float32)
    ckpt.save(ckdir, start_round, params,
              extra={"accountant": {"epsilon": pz.dp.epsilon,
                                    "delta": pz.dp.delta, "spent": spent},
                     "round": start_round})


def test_privacy_guard_trips_mid_chunk(tiny_model, make_pz, make_pipeline,
                                       tmp_path):
    """A resumed run whose remaining budget dies inside a chunk must stop at
    the exact round the per-round loop stops at, with zero overspend."""
    pz = make_pz(scheme="static", rounds=12)
    trip_after = 3          # rounds 2,3,4 run; round 5 trips (mid-chunk of 8)
    results = {}
    for engine in ("loop", "scan"):
        ck = str(tmp_path / engine)
        _near_exhausted_checkpoint(tiny_model, pz, ck, start_round=2,
                                   affordable=trip_after)
        results[engine] = fedsim.run(
            tiny_model, pz, make_pipeline(), rounds=12, engine=engine,
            chunk_rounds=8, checkpoint_dir=ck)
    loop, scan = results["loop"], results["scan"]
    assert loop.privacy_exhausted_at == 2 + trip_after
    assert scan.privacy_exhausted_at == loop.privacy_exhausted_at
    assert scan.losses == loop.losses
    assert len(scan.losses) == trip_after
    assert scan.privacy_spent <= scan.privacy_budget * (1 + 1e-6)
    assert scan.privacy_spent == loop.privacy_spent


def test_privacy_guard_trips_at_chunk_head(tiny_model, make_pz,
                                           make_pipeline, tmp_path):
    """Zero affordable rounds: the engine must stop before dispatching."""
    pz = make_pz(scheme="static", rounds=12)
    ck = str(tmp_path / "ck")
    _near_exhausted_checkpoint(tiny_model, pz, ck, start_round=2,
                               affordable=0)
    res = fedsim.run(tiny_model, pz, make_pipeline(), rounds=12,
                     engine="scan", chunk_rounds=8, checkpoint_dir=ck)
    assert res.privacy_exhausted_at == 2
    assert res.losses == []
