"""Scan-over-rounds engine: equivalence with the per-round loop.

The contract under test: engine="scan" is *bitwise* identical to
engine="loop" at fixed seed — same losses, same p_hats, same privacy spend,
same hard privacy stop — while dispatching chunk_rounds rounds per device
call. Chunk boundaries are deliberately chosen NOT to divide the horizon so
partial chunks are exercised.
"""
import numpy as np
import pytest

import jax

from repro.channel import RayleighFading
from repro.checkpoint import checkpoint as ckpt
from repro.core import dp, engine as eng, fedsim, pairzero
from repro.core import power_control as pc
from repro.models import registry


# ---------------------------------------------------------------------------
# Control-trace precomputation == per-round make_control
# ---------------------------------------------------------------------------

def test_control_trace_matches_make_control(make_pz):
    pz = make_pz(scheme="solution", rounds=16)
    h = RayleighFading().realize(pz.seed ^ 0xC4A7, 16, pz.n_clients).h
    sched = pc.make_schedule(
        "analog", "solution", h, power=100.0, n0=1.0, gamma=5.0,
        n_clients=pz.n_clients, e0=pz.power.e0,
        contraction_a=pz.power.contraction_a,
        contraction_a_tilde=pz.power.contraction_a_tilde,
        epsilon=5.0, delta=0.01)
    trace = eng.build_trace(sched, pz, 3, 16)
    for t in range(3, 16):
        ctl = pairzero.make_control(t, sched, pz.seed, pz.n_clients)
        for key in ctl:
            np.testing.assert_array_equal(
                np.asarray(ctl[key]), np.asarray(trace.ctl[key][t - 3]),
                err_msg=f"round {t} field {key}")


def test_fault_trace_replays_loop_order(make_pz):
    """Chunked trace building consumes the stateful FaultModel RNG in the
    same order the per-round loop does."""
    from repro.runtime.fault import FaultModel, combined_mask
    pz = make_pz(rounds=10, scheme="perfect")
    sched = pc.PowerSchedule(c=np.ones(10), sigma=np.zeros((10, 5)),
                             scheme="perfect", n0=0.0)
    fm_loop = FaultModel(5, dropout_p=0.3, straggler_p=0.1, seed=7)
    loop_masks = [combined_mask(t, fm_loop, None, n_clients=5)
                  for t in range(10)]
    fm_scan = FaultModel(5, dropout_p=0.3, straggler_p=0.1, seed=7)
    tr_a = eng.build_trace(sched, pz, 0, 6, fault=fm_scan)
    tr_b = eng.build_trace(sched, pz, 6, 10, fault=fm_scan)
    scan_masks = np.concatenate([np.asarray(tr_a.ctl["mask"]),
                                 np.asarray(tr_b.ctl["mask"])])
    np.testing.assert_array_equal(np.stack(loop_masks), scan_masks)


def test_chunk_boundaries_align_to_cadences():
    # plain chunking
    assert eng.chunk_boundaries(0, 10, 4) == [(0, 4), (4, 8), (8, 10)]
    # eval every 5 forces a cut at 5 even though the chunk would span it
    assert eng.chunk_boundaries(0, 12, 8, (5,)) == \
        [(0, 5), (5, 10), (10, 12)]
    # resume from mid-cadence: first cut lands back on the cadence grid
    assert eng.chunk_boundaries(3, 12, 8, (5,)) == [(3, 5), (5, 10), (10, 12)]
    # degenerate chunk size still advances
    assert eng.chunk_boundaries(0, 3, 0) == [(0, 1), (1, 2), (2, 3)]


# ---------------------------------------------------------------------------
# Bitwise scan == loop (the acceptance-criterion test)
# ---------------------------------------------------------------------------

def test_scan_bitwise_identical_to_loop_opt125m(opt125m_reduced, make_pz,
                                                make_pipeline):
    """8 rounds of the paper's architecture (reduced): identical trajectory
    bit for bit, across uneven chunk boundaries (3+3+2)."""
    cfg = opt125m_reduced
    pz = make_pz(scheme="solution", n_perturb=1, rounds=8)
    pipe = lambda: make_pipeline(vocab=cfg.vocab_size, seq=32, batch=4)
    res_loop = fedsim.run(cfg, pz, pipe(), rounds=8, engine="loop")
    res_scan = fedsim.run(cfg, pz, pipe(), rounds=8, engine="scan",
                          chunk_rounds=3)
    assert res_scan.losses == res_loop.losses          # bitwise, not allclose
    assert res_scan.p_hats == res_loop.p_hats
    assert res_scan.privacy_spent == res_loop.privacy_spent
    assert len(res_scan.losses) == 8


def test_scan_matches_loop_fo_variant(tiny_model, make_pz, make_pipeline):
    """FO baseline under scan: fp-tolerance equivalence only — XLA fuses
    value_and_grad differently inside the scan body (see fedsim.run
    docstring). Bit-identity is guaranteed for the ZO variants only."""
    pz = make_pz(variant="fo", scheme="perfect", lr=3e-3, rounds=6)
    pipe = lambda: make_pipeline()
    res_loop = fedsim.run(tiny_model, pz, pipe(), rounds=6, engine="loop")
    res_scan = fedsim.run(tiny_model, pz, pipe(), rounds=6, engine="scan",
                          chunk_rounds=4)
    np.testing.assert_allclose(res_scan.losses, res_loop.losses,
                               rtol=1e-5, atol=1e-5)


def test_scan_matches_loop_sign_variant(tiny_model, make_pz, make_pipeline):
    pz = make_pz(variant="sign", scheme="solution", lr=2e-2, rounds=6)
    pipe = lambda: make_pipeline()
    res_loop = fedsim.run(tiny_model, pz, pipe(), rounds=6, engine="loop")
    res_scan = fedsim.run(tiny_model, pz, pipe(), rounds=6, engine="scan",
                          chunk_rounds=4)
    assert res_scan.losses == res_loop.losses


def test_scan_metrics_and_on_round(tiny_model, make_pz, make_pipeline):
    """on_round fires once per round with per-round (not stacked) metrics."""
    pz = make_pz(scheme="perfect", rounds=5)
    seen = []
    fedsim.run(tiny_model, pz, make_pipeline(), rounds=5, engine="scan",
               chunk_rounds=2,
               on_round=lambda t, m: seen.append((t, m["p_clients"].shape)))
    assert [t for t, _ in seen] == [0, 1, 2, 3, 4]
    assert all(shape == (5,) for _, shape in seen)


# ---------------------------------------------------------------------------
# Checkpoint/resume across chunk boundaries
# ---------------------------------------------------------------------------

def test_scan_checkpoint_resume_equivalence(tiny_model, make_pz,
                                            make_pipeline, tmp_path):
    """Interrupt a scan run at a chunk-interior checkpoint cadence, resume
    with a different chunking — the tail must match the uninterrupted loop
    run bitwise."""
    pz = make_pz(scheme="solution", rounds=8)
    pipe = lambda: make_pipeline()
    res_ref = fedsim.run(tiny_model, pz, pipe(), rounds=8, engine="loop")

    ck = str(tmp_path / "ck")
    fedsim.run(tiny_model, pz, pipe(), rounds=4, engine="scan",
               chunk_rounds=3, checkpoint_dir=ck, checkpoint_every=4)
    res_res = fedsim.run(tiny_model, pz, pipe(), rounds=8, engine="scan",
                         chunk_rounds=3, checkpoint_dir=ck,
                         checkpoint_every=1000)
    assert res_res.resumed_from == 4
    assert res_res.losses == res_ref.losses[4:]
    # and the DP ledger picked up where the interrupted run left it
    assert res_res.privacy_spent == pytest.approx(res_ref.privacy_spent)


# ---------------------------------------------------------------------------
# Hard privacy stop, mid-chunk
# ---------------------------------------------------------------------------

def _near_exhausted_checkpoint(cfg, pz, ckdir, start_round, affordable):
    """Write a checkpoint whose accountant affords exactly `affordable` more
    rounds of pz's schedule past `start_round` — the next chunk must trip
    mid-flight."""
    horizon = pz.rounds
    h = RayleighFading().realize(pz.seed ^ 0xC4A7, horizon,
                                 pz.n_clients).h
    sched = pc.make_schedule(
        pz.variant, pz.power.scheme, h, power=pz.channel.power,
        n0=pz.channel.n0, gamma=pz.zo.clip_gamma, n_clients=pz.n_clients,
        e0=pz.power.e0, contraction_a=pz.power.contraction_a,
        contraction_a_tilde=pz.power.contraction_a_tilde,
        epsilon=pz.dp.epsilon, delta=pz.dp.delta)
    budget = dp.r_dp(pz.dp.epsilon, pz.dp.delta)
    costs = [dp.round_privacy_cost(float(sched.c[t]), pz.zo.clip_gamma,
                                   sched.effective_noise_std(t))
             for t in range(start_round, start_round + affordable + 1)]
    # afford the first `affordable` rounds but not the one after
    spent = budget - sum(costs[:affordable]) - 0.5 * costs[affordable]
    import jax.numpy as jnp
    params = registry.init_params(jax.random.key(pz.seed), cfg, jnp.float32)
    ckpt.save(ckdir, start_round, params,
              extra={"accountant": {"epsilon": pz.dp.epsilon,
                                    "delta": pz.dp.delta, "spent": spent},
                     "round": start_round})


def test_privacy_guard_trips_mid_chunk(tiny_model, make_pz, make_pipeline,
                                       tmp_path):
    """A resumed run whose remaining budget dies inside a chunk must stop at
    the exact round the per-round loop stops at, with zero overspend."""
    pz = make_pz(scheme="static", rounds=12)
    trip_after = 3          # rounds 2,3,4 run; round 5 trips (mid-chunk of 8)
    results = {}
    for engine in ("loop", "scan"):
        ck = str(tmp_path / engine)
        _near_exhausted_checkpoint(tiny_model, pz, ck, start_round=2,
                                   affordable=trip_after)
        results[engine] = fedsim.run(
            tiny_model, pz, make_pipeline(), rounds=12, engine=engine,
            chunk_rounds=8, checkpoint_dir=ck)
    loop, scan = results["loop"], results["scan"]
    assert loop.privacy_exhausted_at == 2 + trip_after
    assert scan.privacy_exhausted_at == loop.privacy_exhausted_at
    assert scan.losses == loop.losses
    assert len(scan.losses) == trip_after
    assert scan.privacy_spent <= scan.privacy_budget * (1 + 1e-6)
    assert scan.privacy_spent == loop.privacy_spent


def test_privacy_guard_trips_at_chunk_head(tiny_model, make_pz,
                                           make_pipeline, tmp_path):
    """Zero affordable rounds: the engine must stop before dispatching."""
    pz = make_pz(scheme="static", rounds=12)
    ck = str(tmp_path / "ck")
    _near_exhausted_checkpoint(tiny_model, pz, ck, start_round=2,
                               affordable=0)
    res = fedsim.run(tiny_model, pz, make_pipeline(), rounds=12,
                     engine="scan", chunk_rounds=8, checkpoint_dir=ck)
    assert res.privacy_exhausted_at == 2
    assert res.losses == []
