"""Byzantine subsystem: behavior/defense registries, neutrality, defenses.

The contracts under test:

  * registries — the four behaviors and three defenses resolve by name,
    unknown names raise, instances are frozen/hashable (they join the
    make_zo_step memo key);
  * neutrality — a zero-fraction behavior config and defense="none"
    reproduce the pre-subsystem trajectory BITWISE on loop and scan, and
    structurally (the historical program never calls into repro.byzantine
    at all: no "byz" control row, no behavior hook in the traced step);
  * the sign_flip pin — the registered behavior's trajectory is bitwise
    what an independently-written inline negation produces (the legacy
    fig4 inline-adversary contract);
  * defenses — clip bounds the radiated payload and prices its DP against
    the tightened gamma_d schedule; the grouped robust decode tolerates a
    sign-flipping minority in its masked median; reweight bills its
    residual feedback through Transport accounting.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import byzantine as byz
from repro.byzantine import behaviors as bz_behaviors
from repro.byzantine import defenses as bz_defenses
from repro.configs.base import (ByzantineConfig, ChannelConfig, DPConfig,
                                PairZeroConfig, PowerControlConfig,
                                TransportConfig, ZOConfig)
from repro.core import fedsim, pairzero
from repro.core import power_control as pc
from repro.core import transport as tp


def make_bpz(mechanism="analog", scheme="solution", rounds=8, seed=0,
             n_clients=8, byzantine=None, gamma=5.0):
    """PairZeroConfig speaking TransportConfig, with an optional attack."""
    return PairZeroConfig(
        n_clients=n_clients, rounds=rounds,
        zo=ZOConfig(mu=1e-3, lr=5e-3, clip_gamma=gamma, n_perturb=1),
        channel=ChannelConfig(n0=1.0, power=100.0),
        dp=DPConfig(epsilon=5.0, delta=0.01),
        power=PowerControlConfig(scheme=scheme),
        transport=TransportConfig(mechanism, scheme),
        byzantine=byzantine, seed=seed)


# ---------------------------------------------------------------------------
# Registries & protocol
# ---------------------------------------------------------------------------

def test_behavior_registry():
    assert set(byz.available_behaviors()) >= {
        "sign_flip", "scaled_poison", "gaussian_noise", "colluding_cohort"}
    assert byz.get_behavior("sign_flip") is byz.SignFlip
    with pytest.raises(ValueError, match="unknown behavior"):
        byz.get_behavior("rubber_hose")


def test_defense_registry():
    assert set(byz.available_defenses()) >= {
        "clip", "robust_decode", "reweight"}
    assert byz.get_defense("clip") is byz.TransmitClip
    with pytest.raises(ValueError, match="unknown defense"):
        byz.get_defense("hope")


def test_resolution_from_config():
    pz = make_bpz()
    assert byz.resolve_behavior(pz) is None
    assert byz.resolve_defense(pz) is None
    pz0 = make_bpz(byzantine=ByzantineConfig(behavior="sign_flip",
                                             fraction=0.0))
    assert byz.resolve_behavior(pz0) is None      # zero fraction: no attack
    pza = make_bpz(byzantine=ByzantineConfig(behavior="sign_flip",
                                             fraction=0.25, defense="clip"))
    assert isinstance(byz.resolve_behavior(pza), byz.SignFlip)
    assert isinstance(byz.resolve_defense(pza), byz.TransmitClip)


def test_instances_are_hashable_memo_keys(tiny_model):
    b = byz.SignFlip(fraction=0.25, seed=0)
    assert hash(b) == hash(byz.SignFlip(fraction=0.25, seed=0))
    d = byz.TransmitClip(clip=2.5)
    assert hash(d) == hash(byz.TransmitClip(clip=2.5))
    pz = make_bpz()
    s1 = pairzero.make_zo_step(tiny_model, pz, behavior=b, defense=d)
    s2 = pairzero.make_zo_step(tiny_model, pz,
                               behavior=byz.SignFlip(fraction=0.25, seed=0),
                               defense=byz.TransmitClip(clip=2.5))
    assert s1 is s2                       # lru_cache hit on equal instances
    s3 = pairzero.make_zo_step(tiny_model, pz)
    assert s3 is not s1                   # attack-off is a distinct program


def test_client_mask_counts_and_determinism():
    b = byz.SignFlip(fraction=0.25, seed=3)
    m = b.client_mask(8)
    assert m.shape == (8,) and m.dtype == np.float32
    assert m.sum() == 2                   # round(0.25 * 8)
    np.testing.assert_array_equal(m, byz.SignFlip(fraction=0.25,
                                                  seed=3).client_mask(8))
    assert not np.array_equal(m, byz.SignFlip(fraction=0.25,
                                              seed=4).client_mask(8))
    assert byz.SignFlip(fraction=1.0, seed=0).client_mask(8).sum() == 8


def test_fo_transport_rejects_byzantine():
    pz = make_bpz("fo", scheme="perfect",
                  byzantine=ByzantineConfig(behavior="sign_flip",
                                            fraction=0.25))
    with pytest.raises(ValueError, match="FO baseline"):
        fedsim.Experiment(None, pz, None, rounds=4)


# ---------------------------------------------------------------------------
# Neutrality: zero fraction / no defense is the historical program
# ---------------------------------------------------------------------------

def test_zero_fraction_bitwise_neutral(tiny_model, make_pipeline):
    """ByzantineConfig with fraction=0 (and defense='none') reproduces the
    no-config trajectory bitwise on both single-device engines."""
    pz = make_bpz(rounds=7)
    pz0 = dataclasses.replace(pz, byzantine=ByzantineConfig(
        behavior="sign_flip", fraction=0.0, defense="none"))
    pipe = lambda: make_pipeline(n_clients=8, batch=2)
    ref = fedsim.run(tiny_model, pz, pipe(), rounds=7, engine="scan",
                     chunk_rounds=3)
    for engine, kw in (("loop", {}), ("scan", {"chunk_rounds": 3})):
        res = fedsim.run(tiny_model, pz0, pipe(), rounds=7, engine=engine,
                         **kw)
        assert res.losses == ref.losses, engine
        assert res.p_hats == ref.p_hats, engine
        assert res.privacy_spent == ref.privacy_spent, engine


def test_neutrality_is_structural(tiny_model, make_pipeline, monkeypatch):
    """The clean program never calls into repro.byzantine: poison the
    behavior hook and the control row — an inactive config must not even
    reach them (same pattern as the fused-flag-off structural pin)."""
    def boom(*a, **kw):
        raise AssertionError("byzantine path entered on a clean run")
    monkeypatch.setattr(bz_behaviors, "apply_behavior", boom)
    pairzero.make_zo_step.cache_clear()
    try:
        pz = make_bpz(rounds=4, byzantine=ByzantineConfig(
            behavior="sign_flip", fraction=0.0))
        res = fedsim.run(tiny_model, pz, make_pipeline(n_clients=8, batch=2),
                         rounds=4, engine="scan", chunk_rounds=2)
        assert len(res.losses) == 4
    finally:
        pairzero.make_zo_step.cache_clear()


def test_control_row_only_when_active():
    pz = make_bpz()
    spec = pairzero.control_spec(pz.n_clients)
    assert "byz" not in spec
    b = byz.SignFlip(fraction=0.25)
    spec_a = pairzero.control_spec(pz.n_clients, behavior=b)
    assert spec_a["byz"].shape == (pz.n_clients,)


# ---------------------------------------------------------------------------
# The sign_flip pin: registered behavior == independent inline negation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _InlineNegation(bz_behaviors.ClientBehavior):
    """The legacy fig4-style inline adversary, written independently:
    multiply by (1 - 2 * mask) instead of jnp.where-selecting -p."""

    def apply(self, p, mask, ctl, key, offset, k_total):
        return p * (1.0 - 2.0 * mask)


def test_sign_flip_pins_inline_negation(tiny_model, make_pipeline):
    """Trajectory under the registered sign_flip is bitwise the inline
    negation's (multiplying by -1.0 is exact in IEEE-754), so retiring an
    inline adversary for the registry entry is observationally free."""
    pz = make_bpz(rounds=6, byzantine=ByzantineConfig(behavior="sign_flip",
                                                      fraction=0.25))
    pipe = lambda: make_pipeline(n_clients=8, batch=2)
    reg = fedsim.run(tiny_model, pz, pipe(), rounds=6, engine="scan",
                     chunk_rounds=3)
    inline = fedsim.run(tiny_model, pz, pipe(), rounds=6, engine="scan",
                        chunk_rounds=3,
                        behavior=_InlineNegation(fraction=0.25, seed=0))
    clean = fedsim.run(tiny_model, make_bpz(rounds=6), pipe(), rounds=6,
                       engine="scan", chunk_rounds=3)
    assert reg.losses == inline.losses
    assert reg.p_hats == inline.p_hats
    assert reg.losses != clean.losses     # and the attack actually bites


def test_attack_moves_trajectory_loop_eq_scan(tiny_model, make_pipeline):
    for behavior in ("scaled_poison", "colluding_cohort"):
        pz = make_bpz(rounds=6, byzantine=ByzantineConfig(
            behavior=behavior, fraction=0.25))
        pipe = lambda: make_pipeline(n_clients=8, batch=2)
        r_scan = fedsim.run(tiny_model, pz, pipe(), rounds=6, engine="scan",
                            chunk_rounds=3)
        r_loop = fedsim.run(tiny_model, pz, pipe(), rounds=6, engine="loop")
        assert r_scan.losses == r_loop.losses, behavior


# ---------------------------------------------------------------------------
# Defenses
# ---------------------------------------------------------------------------

def test_clip_bounds_radiated_payload():
    d = byz.TransmitClip(clip=1.5)
    p = jnp.asarray([-20.0, -1.0, 0.0, 3.0, 40.0])
    out = np.asarray(d.transmit(p, {}))
    assert np.all(np.abs(out) <= 1.5)
    np.testing.assert_array_equal(out, [-1.5, -1.0, 0.0, 1.5, 1.5])


def test_clip_from_config_scales_gamma():
    pz = make_bpz(byzantine=ByzantineConfig(behavior="sign_flip",
                                            fraction=0.25, defense="clip",
                                            clip_factor=0.5))
    d = byz.resolve_defense(pz)
    assert d.clip == pytest.approx(0.5 * pz.zo.clip_gamma)


def test_defended_config_tightens_gamma():
    pz = make_bpz(gamma=5.0)
    dz = pc.defended_config(pz, 2.5)
    assert dz.zo.clip_gamma == 2.5
    assert pc.defended_config(pz, 5.0) is pz      # no-op stays identical
    assert pc.defended_config(pz, 9.0) is pz      # looser clip never binds


def test_clip_dp_pricing_matches_defended_schedule():
    """The clip defense's accounting IS the transport's, evaluated on the
    gamma_d-tightened config: sensitivity 2*gamma_d, re-solved schedule."""
    pz = make_bpz(gamma=5.0)
    transport = tp.resolve(pz)
    d = byz.TransmitClip(clip=2.5)
    h = np.abs(np.random.default_rng(0).normal(size=(6, pz.n_clients)))
    dz = pc.defended_config(pz, 2.5)
    sched = d.make_schedule(transport, h, pz)
    sched_ref = transport.make_schedule(h, dz)
    np.testing.assert_array_equal(sched.c, sched_ref.c)
    assert d.charges_privacy(transport, sched, pz) \
        == transport.charges_privacy(sched_ref, dz)
    np.testing.assert_allclose(
        np.asarray(d.round_dp_costs(transport, sched, 0, 6, pz)),
        np.asarray(transport.round_dp_costs(sched_ref, 0, 6, dz)))
    assert d.audited_pz(pz).zo.clip_gamma == 2.5


def test_masked_median_ignores_invalid_slots():
    vals = jnp.asarray([5.0, -3.0, 100.0, 2.0])
    valid = jnp.asarray([True, True, False, True])
    med = float(bz_defenses._masked_median(vals, valid))
    assert med == pytest.approx(2.0)      # median of {5, -3, 2}
    med_all = float(bz_defenses._masked_median(
        vals, jnp.ones(4, dtype=bool)))
    assert med_all == pytest.approx(3.5)  # even count: mean of middle two


def test_group_assignment_partitions_clients():
    key = jax.random.key(0)
    groups = 4
    g_of = np.asarray(bz_defenses._group_assignment(key, 8, groups))
    assert g_of.shape == (8,)
    counts = np.bincount(g_of, minlength=groups)
    np.testing.assert_array_equal(counts, [2, 2, 2, 2])


def test_robust_decode_recovers_under_scaled_poison(tiny_model,
                                                    make_pipeline):
    """Singleton sub-slots (groups = K) make the decode a coordinate
    median across clients: with 2/8 poisoning at λ = 20 the median
    discards the out-of-range payloads the mean cannot, so the defended
    run must land closer to the clean trajectory than the undefended one.
    (The attack has to hurt MORE than the sub-slot decode noise — a
    singleton decode is ~K× noisier than the full superposition — which
    is why this pin uses a heavy λ at a short horizon; the 60-round
    defended-vs-undefended sweep lives in benchmarks/fig_robustness.py.)"""
    pipe = lambda: make_pipeline(n_clients=8, batch=2)
    clean = fedsim.run(tiny_model, make_bpz(rounds=8), pipe(), rounds=8,
                       engine="scan", chunk_rounds=4)
    atk = ByzantineConfig(behavior="scaled_poison", fraction=0.25,
                          scale=20.0)
    und = fedsim.run(tiny_model, make_bpz(rounds=8, byzantine=atk), pipe(),
                     rounds=8, engine="scan", chunk_rounds=4)
    dfd = fedsim.run(
        tiny_model,
        make_bpz(rounds=8, byzantine=dataclasses.replace(
            atk, defense="robust_decode", groups=8)),
        pipe(), rounds=8, engine="scan", chunk_rounds=4)
    gap_und = abs(np.mean(und.losses[-3:]) - np.mean(clean.losses[-3:]))
    gap_dfd = abs(np.mean(dfd.losses[-3:]) - np.mean(clean.losses[-3:]))
    assert gap_und > 0.5          # the attack really hurts undefended
    assert gap_dfd < gap_und      # ... and the median decode recovers


def test_reweight_bills_feedback_bits(tiny_model, make_pipeline):
    """The residual-reweight defense feeds back one residual per group and
    round — priced through Transport accounting as extra downlink bits."""
    atk = ByzantineConfig(behavior="sign_flip", fraction=0.25,
                          defense="reweight", groups=4)
    pipe = lambda: make_pipeline(n_clients=8, batch=2)
    und = fedsim.run(tiny_model, make_bpz(rounds=6), pipe(), rounds=6,
                     engine="scan", chunk_rounds=3)
    dfd = fedsim.run(tiny_model, make_bpz(rounds=6, byzantine=atk), pipe(),
                     rounds=6, engine="scan", chunk_rounds=3)
    assert dfd.uplink_bits == und.uplink_bits + 4 * 6


def test_defense_without_attack_is_allowed(tiny_model, make_pipeline):
    """Defense-only configs run (paranoid server, no actual adversary) —
    and clip changes the schedule, so the trajectory legitimately moves."""
    bz = ByzantineConfig(behavior="none", fraction=0.0, defense="clip")
    pz = make_bpz(rounds=5, byzantine=bz)
    assert byz.resolve_behavior(pz) is None
    assert isinstance(byz.resolve_defense(pz), byz.TransmitClip)
    res = fedsim.run(tiny_model, pz, make_pipeline(n_clients=8, batch=2),
                     rounds=5, engine="scan", chunk_rounds=3)
    assert len(res.losses) == 5
