"""Zeroth-order estimator: SPSA algebra, seeds, memory-chain equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import zo


@pytest.fixture
def quad():
    a = jax.random.normal(jax.random.key(0), (24, 24))
    a = a @ a.T / 24 + jnp.eye(24)
    params = {"x": jax.random.normal(jax.random.key(1), (24,)),
              "y": jax.random.normal(jax.random.key(2), (8, 3))}

    def loss(p):
        return (0.5 * p["x"] @ a @ p["x"] + jnp.sum(jnp.sin(p["y"]))
                + jnp.sum(p["x"]))

    return loss, params


def test_projection_approximates_directional_derivative(quad):
    loss, params = quad
    for seed in (3, 11, 17):
        lp, lm, _ = zo.dual_forward(loss, params, seed, 1e-4, mode="fresh")
        proj = float(zo.projection(lp, lm, 1e-4, 1e9))
        dd = float(zo.directional_derivative(loss, params, seed))
        assert abs(proj - dd) < 1e-2 * max(1.0, abs(dd)), (seed, proj, dd)


def test_projection_clipping():
    p = zo.projection(jnp.float32(500.0), jnp.float32(0.0), 1e-3, 5.0)
    assert float(p) == 5.0
    p = zo.projection(jnp.float32(0.0), jnp.float32(500.0), 1e-3, 5.0)
    assert float(p) == -5.0


def test_chained_equals_fresh(quad):
    loss, params = quad
    lp_c, lm_c, at = zo.dual_forward(loss, params, 5, 1e-3, mode="chained")
    lp_f, lm_f, _ = zo.dual_forward(loss, params, 5, 1e-3, mode="fresh")
    assert abs(float(lp_c - lp_f)) < 1e-4
    assert abs(float(lm_c - lm_f)) < 1e-4
    upd_c = zo.apply_update(at, 5, jnp.float32(0.7), 0.01, 1e-3,
                            mode="chained")
    upd_f = zo.apply_update(params, 5, jnp.float32(0.7), 0.01, 1e-3,
                            mode="fresh")
    for k in params:
        np.testing.assert_allclose(np.asarray(upd_c[k]),
                                   np.asarray(upd_f[k]), atol=1e-5)


def test_perturb_uses_independent_per_leaf_streams():
    params = {"a": jnp.zeros((64,)), "b": jnp.zeros((64,))}
    z = zo.draw_z(params, 9)
    assert not np.allclose(np.asarray(z["a"]), np.asarray(z["b"]))


def test_round_seed_deterministic_and_distinct():
    s1 = zo.round_seed(0, 5)
    s2 = zo.round_seed(0, 5)
    s3 = zo.round_seed(0, 6)
    s4 = zo.round_seed(1, 5)
    assert int(s1) == int(s2)
    assert int(s1) != int(s3)
    assert int(s1) != int(s4)


def test_spsa_gradient_unbiased_direction(quad):
    """Averaged over many seeds, SPSA ≈ the true gradient (cosine > 0.7)."""
    loss, params = quad
    true_grad = jax.grad(loss)(params)
    acc = jax.tree_util.tree_map(jnp.zeros_like, params)
    n = 200
    for seed in range(n):
        g = zo.spsa_gradient(loss, params, seed, 1e-4)
        acc = jax.tree_util.tree_map(lambda a, b: a + b / n, acc, g)
    dot = sum(float(jnp.vdot(acc[k], true_grad[k])) for k in params)
    na = np.sqrt(sum(float(jnp.vdot(acc[k], acc[k])) for k in params))
    nb = np.sqrt(sum(float(jnp.vdot(true_grad[k], true_grad[k]))
                     for k in params))
    assert dot / (na * nb) > 0.7


def test_zo_descends_quadratic(quad):
    loss, params = quad
    l0 = float(loss(params))
    for t in range(300):
        seed = zo.round_seed(0, t)
        lp, lm, at = zo.dual_forward(loss, params, seed, 1e-4,
                                     mode="chained")
        p = zo.projection(lp, lm, 1e-4, 100.0)
        params = zo.apply_update(at, seed, p, 0.01, 1e-4, mode="chained")
    assert float(loss(params)) < 0.5 * l0
