"""Client-mesh lane: shard_map scan engine ≡ single-device engine, bitwise.

These tests need a multi-device host. CI runs them in a dedicated lane:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_mesh_engine.py -q

On a single-device host every test skips (the flag must be set before the
first jax import, so it cannot be applied from inside the suite).

The contract under test: with `mesh=`, the per-client dual forward runs
shard_map'd over the mesh's (pod, data) client axes and the Transport's
scalar decode consumes a genuine cross-device `jax.lax.psum` (asserted
against the compiled HLO) — while the loss/p_hat/privacy trajectory stays
*bitwise* identical to the single-device engines at fixed seed.
"""
import numpy as np
import pytest

import jax

from repro.channel import RayleighFading
from repro.core import fedsim, pairzero
from repro.core import transport as tp
from repro.launch.mesh import make_client_mesh
from repro.models import registry
from repro.runtime import sharding as shd

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="client-mesh lane needs >= 8 devices (set XLA_FLAGS="
           "--xla_force_host_platform_device_count=8 before jax imports)")


@pytest.fixture(scope="module")
def mesh8():
    return make_client_mesh("8")


def _runs(cfg, pz, make_pipeline, mesh, *, rounds=6, chunk=4, **kw):
    pipe = lambda: make_pipeline(vocab=cfg.vocab_size, n_clients=8, batch=2,
                                 seq=16)
    ref = fedsim.run(cfg, pz, pipe(), rounds=rounds, engine="scan",
                     chunk_rounds=chunk, **kw)
    res = fedsim.run(cfg, pz, pipe(), rounds=rounds, engine="scan",
                     chunk_rounds=chunk, mesh=mesh, **kw)
    return ref, res


# ---------------------------------------------------------------------------
# Bitwise identity: mesh scan == single-device scan (== loop)
# ---------------------------------------------------------------------------

def test_mesh_scan_bitwise_analog_opt125m(opt125m_reduced, make_pz,
                                          make_pipeline, mesh8):
    """The acceptance-criterion test, on the paper's own architecture:
    8 clients shard_map'd over an 8-device ('data',) mesh, uneven chunks."""
    pz = make_pz(scheme="solution", n_perturb=1, rounds=8, n_clients=8)
    pipe = lambda: make_pipeline(vocab=opt125m_reduced.vocab_size,
                                 n_clients=8, batch=2, seq=16)
    res_loop = fedsim.run(opt125m_reduced, pz, pipe(), rounds=8,
                          engine="loop")
    res_scan = fedsim.run(opt125m_reduced, pz, pipe(), rounds=8,
                          engine="scan", chunk_rounds=3)
    res_mesh = fedsim.run(opt125m_reduced, pz, pipe(), rounds=8,
                          engine="scan", chunk_rounds=3, mesh=mesh8)
    assert res_mesh.losses == res_scan.losses == res_loop.losses
    assert res_mesh.p_hats == res_scan.p_hats
    assert res_mesh.privacy_spent == res_scan.privacy_spent
    assert len(res_mesh.losses) == 8


def test_mesh_scan_bitwise_sign(tiny_model, make_pz, make_pipeline, mesh8):
    pz = make_pz(variant="sign", scheme="solution", lr=2e-2, rounds=6,
                 n_clients=8)
    ref, res = _runs(tiny_model, pz, make_pipeline, mesh8)
    assert res.losses == ref.losses
    assert res.p_hats == ref.p_hats


def test_mesh_scan_bitwise_digital(tiny_model, make_pz, make_pipeline,
                                   mesh8):
    """The quantizer draws from the replicated round key, so the digital
    baseline is bit-identical under the mesh too."""
    pz = make_pz(scheme="perfect", rounds=6, n_clients=8)
    transport = tp.DigitalTDMA(quant_bits=8, clip=float(pz.zo.clip_gamma))
    ref, res = _runs(tiny_model, pz, make_pipeline, mesh8,
                     transport=transport)
    assert res.losses == ref.losses


def test_mesh_multiple_clients_per_shard(tiny_model, make_pz,
                                         make_pipeline):
    """K=8 over 4 shards (2 clients per device) — the gather reassembles
    multi-client slices, not just scalars."""
    mesh4 = make_client_mesh("4")
    pz = make_pz(scheme="solution", rounds=6, n_clients=8)
    ref, res = _runs(tiny_model, pz, make_pipeline, mesh4)
    assert res.losses == ref.losses


def test_mesh_pod_data_axes(tiny_model, make_pz, make_pipeline):
    """(pod=2, data=4): client ids linearize pod-major, matching the
    PartitionSpec(('pod','data')) batch tiling."""
    mesh2x4 = make_client_mesh("2x4")
    pz = make_pz(scheme="solution", rounds=6, n_clients=8)
    ref, res = _runs(tiny_model, pz, make_pipeline, mesh2x4)
    assert res.losses == ref.losses
    assert res.p_hats == ref.p_hats


def test_mesh_loop_engine_bitwise(tiny_model, make_pz, make_pipeline,
                                  mesh8):
    """The shard_map'd step under per-round dispatch (engine='loop') —
    executors only change dispatch granularity, never numerics."""
    pz = make_pz(scheme="solution", rounds=5, n_clients=8)
    pipe = lambda: make_pipeline(vocab=tiny_model.vocab_size, n_clients=8,
                                 batch=2, seq=16)
    ref = fedsim.run(tiny_model, pz, pipe(), rounds=5, engine="loop")
    res = fedsim.run(tiny_model, pz, pipe(), rounds=5, engine="loop",
                     mesh=mesh8)
    assert res.losses == ref.losses


def test_mesh_with_model_axis_runs(tiny_model, make_pz, make_pipeline):
    """(data=4, model=2): the 'model' axis stays under GSPMD auto inside
    the shard_map (TP). TP re-tiles contractions, so this is fp-tolerance
    equivalence, not bitwise — the lane proves the partial-auto path
    compiles and trains."""
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((4, 2), ("data", "model"))
    pz = make_pz(scheme="solution", rounds=4, n_clients=8)
    ref, res = _runs(tiny_model, pz, make_pipeline, mesh, rounds=4, chunk=2)
    np.testing.assert_allclose(res.losses, ref.losses, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Byzantine lane: the behavior mask survives shard_map
# ---------------------------------------------------------------------------

def test_mesh_byzantine_attack_bitwise(tiny_model, make_pz, make_pipeline,
                                       mesh8):
    """An attacked+defended run on the mesh is bitwise the single-device
    run: the ctl['byz'] cohort row shards with the control block, each
    shard rewrites only its own client slice, and the grouped robust
    decode consumes the psum-gathered full payload."""
    import dataclasses

    from repro.configs.base import ByzantineConfig
    bz = ByzantineConfig(behavior="sign_flip", fraction=0.25,
                         defense="robust_decode", groups=4)
    pz = dataclasses.replace(
        make_pz(scheme="solution", rounds=6, n_clients=8), byzantine=bz)
    ref, res = _runs(tiny_model, pz, make_pipeline, mesh8)
    assert res.losses == ref.losses
    assert res.p_hats == ref.p_hats
    # and the attack is genuinely on in both runs
    clean = fedsim.run(tiny_model,
                       make_pz(scheme="solution", rounds=6, n_clients=8),
                       make_pipeline(vocab=tiny_model.vocab_size,
                                     n_clients=8, batch=2, seq=16),
                       rounds=6, engine="scan", chunk_rounds=4)
    assert res.losses != clean.losses


def test_mesh_byzantine_noise_behavior_bitwise(tiny_model, make_pz,
                                               make_pipeline, mesh8):
    """gaussian_noise draws the full [K] noise vector then slices at the
    shard offset — the draw-then-slice contract that keeps per-client
    randomness identical however clients are sharded."""
    import dataclasses

    from repro.configs.base import ByzantineConfig
    bz = ByzantineConfig(behavior="gaussian_noise", fraction=0.5, scale=2.0)
    pz = dataclasses.replace(
        make_pz(scheme="solution", rounds=5, n_clients=8), byzantine=bz)
    ref, res = _runs(tiny_model, pz, make_pipeline, mesh8, rounds=5)
    assert res.losses == ref.losses
    # multi-client shards slice interior offsets of the same noise vector
    mesh4 = make_client_mesh("4")
    ref4, res4 = _runs(tiny_model, pz, make_pipeline, mesh4, rounds=5)
    assert res4.losses == ref4.losses == res.losses


def test_mesh_desync_bitwise(tiny_model, make_pz, make_pipeline, mesh8):
    """Active desync on the mesh == single-device, bitwise: the full-[K]
    dsync_stale/dsync_a rows ship replicated with the control block and
    each shard slices its own client window (draw-then-slice, like the
    byzantine noise behavior), while the stale dual forward rides the
    same shard_map body."""
    import dataclasses

    from repro.configs.base import DesyncConfig
    dz = DesyncConfig(fraction=0.5, max_lag=2, phase_std=0.2, seed=0)
    pz = dataclasses.replace(
        make_pz(scheme="solution", rounds=6, n_clients=8), desync=dz)
    ref, res = _runs(tiny_model, pz, make_pipeline, mesh8)
    assert res.losses == ref.losses
    assert res.p_hats == ref.p_hats
    # multi-client shards slice interior offsets of the same stale rows
    mesh4 = make_client_mesh("4")
    ref4, res4 = _runs(tiny_model, pz, make_pipeline, mesh4)
    assert res4.losses == ref4.losses == res.losses
    # and the scenario is genuinely active in the meshed run
    clean = fedsim.run(tiny_model,
                       make_pz(scheme="solution", rounds=6, n_clients=8),
                       make_pipeline(vocab=tiny_model.vocab_size,
                                     n_clients=8, batch=2, seq=16),
                       rounds=6, engine="scan", chunk_rounds=4)
    assert res.p_hats != clean.p_hats


# ---------------------------------------------------------------------------
# Telemetry neutrality on the mesh lane
# ---------------------------------------------------------------------------

def test_mesh_telemetry_is_numerically_passive(tiny_model, make_pz,
                                               make_pipeline, mesh8,
                                               tmp_path):
    """Telemetry ON (tracer + sampler + trilemma ledger + HLO cost
    analysis + health monitor) under an 8-way client mesh vs the default
    OFF: losses, p_hats, and privacy spend stay bitwise identical, the
    ledger's final row equals the mesh run's own RunResult accounting
    exactly, and the introspection sees the mesh program's collectives."""
    from repro import obs
    pz = make_pz(scheme="solution", rounds=6, n_clients=8)
    pipe = lambda: make_pipeline(vocab=tiny_model.vocab_size, n_clients=8,
                                 batch=2, seq=16)
    ref = fedsim.run(tiny_model, pz, pipe(), rounds=6, engine="scan",
                     chunk_rounds=4, mesh=mesh8)
    ledger = str(tmp_path / "mesh_metrics.jsonl")
    res = fedsim.run(tiny_model, pz, pipe(), rounds=6, engine="scan",
                     chunk_rounds=4, mesh=mesh8,
                     telemetry=obs.Telemetry.on(memory_sample_every=2,
                                                cost=True),
                     hooks=[obs.MetricsSink(ledger),
                            obs.HealthMonitor(policy="warn")])
    assert res.losses == ref.losses
    assert res.p_hats == ref.p_hats
    assert res.privacy_spent == ref.privacy_spent
    final = obs.final_row(ledger)
    assert final["bits_cum"] == res.uplink_bits
    assert final["dp_spent_cum"] == res.privacy_spent
    assert final["peak_bytes"] == res.peak_bytes > 0
    assert res.health_abort_round == -1
    # the compiled-program view of the same run: real flops and the
    # client-axis all-reduce the OTA aggregate lowers to
    assert res.cost_stats["flops"] > 0
    assert res.cost_stats["collectives"]["all-reduce"]["count"] >= 1


# ---------------------------------------------------------------------------
# The collective is real: all-reduce in the compiled HLO
# ---------------------------------------------------------------------------

def test_mesh_hlo_contains_client_all_reduce(tiny_model, make_pz,
                                             make_pipeline, mesh8):
    """Structured collective census of the mesh step's compiled HLO
    (repro.obs.hlo): exactly two all-reduces — the OTA scalar aggregate
    (Transport.aggregate_mesh's psum) and the loss mean — both spanning
    the full 8-client axis; the single-device step compiles collective-
    free; and the census byte total agrees with roofline's independent
    HLO parser on the same text."""
    pz = make_pz(scheme="solution", rounds=4, n_clients=8)
    transport = tp.resolve(pz)
    pipe = make_pipeline(vocab=tiny_model.vocab_size, n_clients=8, batch=2,
                         seq=16)
    batch = {k: v for k, v in pipe.batch(0).items() if k != "labels"}
    params = registry.init_params(jax.random.key(0), tiny_model,
                                  jax.numpy.float32)
    h = RayleighFading().realize(pz.seed ^ 0xC4A7, 4, 8).h
    sched = transport.make_schedule(h, pz)
    ctl = pairzero.make_control(0, sched, pz.seed, 8)

    from repro.launch.roofline import collective_bytes
    from repro.obs.hlo import collective_census

    step = pairzero.make_zo_step(tiny_model, pz, transport=transport)
    single = jax.jit(step).lower(params, batch, ctl).compile().as_text()
    assert collective_census(single) == {}

    mstep = pairzero.make_zo_step(tiny_model, pz, transport=transport,
                                  mesh=mesh8)
    args = (jax.device_put(params, shd.params_sharding(mesh8, params)),
            jax.device_put(batch, shd.batch_sharding(mesh8, batch)),
            jax.device_put(ctl, shd.control_sharding(mesh8, ctl)))
    meshed = jax.jit(mstep).lower(*args).compile().as_text()
    census = collective_census(meshed)
    ar = census["all-reduce"]
    assert ar["count"] == 2             # OTA scalar aggregate + loss mean
    assert ar["group_sizes"] == [8, 8]  # each spans the full client axis
    assert ar["bytes"] > 0
    # two independent HLO parsers, one answer: the census byte totals
    # must match roofline's analytic collective model on the same text
    total, by_op = collective_bytes(meshed)
    assert sum(c["bytes"] for c in census.values()) == total
    assert {op: c["bytes"] for op, c in census.items()} == by_op


# ---------------------------------------------------------------------------
# Sharded checkpoint / resume
# ---------------------------------------------------------------------------

def test_mesh_checkpoint_resume_bitwise(tiny_model, make_pz, make_pipeline,
                                        mesh8, tmp_path):
    """Interrupt a mesh run at a chunk-boundary checkpoint, resume on the
    mesh — the tail matches the uninterrupted single-device loop bitwise
    (FSDP-sharded params gather into the npz and reshard on restore)."""
    pz = make_pz(scheme="solution", rounds=8, n_clients=8)
    pipe = lambda: make_pipeline(vocab=tiny_model.vocab_size, n_clients=8,
                                 batch=2, seq=16)
    res_ref = fedsim.run(tiny_model, pz, pipe(), rounds=8, engine="loop")

    ck = str(tmp_path / "ck")
    fedsim.run(tiny_model, pz, pipe(), rounds=4, engine="scan",
               chunk_rounds=4, mesh=mesh8, checkpoint_dir=ck,
               checkpoint_every=4)
    res = fedsim.run(tiny_model, pz, pipe(), rounds=8, engine="scan",
                     chunk_rounds=4, mesh=mesh8, checkpoint_dir=ck,
                     checkpoint_every=1000)
    assert res.resumed_from == 4
    assert res.losses == res_ref.losses[4:]
    assert res.privacy_spent == pytest.approx(res_ref.privacy_spent)


# ---------------------------------------------------------------------------
# Guard rails
# ---------------------------------------------------------------------------

def test_mesh_rejects_indivisible_clients(tiny_model, make_pz,
                                          make_pipeline, mesh8):
    pz = make_pz(rounds=4, n_clients=5)
    with pytest.raises(ValueError, match="divide evenly"):
        fedsim.run(tiny_model, pz, make_pipeline(n_clients=5), rounds=4,
                   engine="scan", mesh=mesh8)


def test_mesh_rejects_fo(tiny_model, make_pz, make_pipeline, mesh8):
    pz = make_pz(variant="fo", scheme="perfect", rounds=4, n_clients=8)
    with pytest.raises(ValueError, match="FO baseline"):
        fedsim.run(tiny_model, pz, make_pipeline(n_clients=8), rounds=4,
                   engine="scan", mesh=mesh8)


# ---------------------------------------------------------------------------
# Privacy capture on the mesh (repro.privacy)
# ---------------------------------------------------------------------------

def test_mesh_observation_capture_bitwise(tiny_model, make_pz,
                                          make_pipeline, mesh8):
    """Eavesdropper capture under shard_map: the observation is computed
    from the psum-gathered [K] payload and the replicated control block,
    so it must be bitwise what the single-device engines record — and
    capture must stay passive on the mesh too."""
    from repro import privacy as pv
    pz = make_pz(scheme="solution", n_perturb=1, rounds=6, n_clients=8)
    pipe = lambda: make_pipeline(vocab=tiny_model.vocab_size, n_clients=8,
                                 batch=2, seq=16)
    h_ref, h_mesh = pv.AttackHook(), pv.AttackHook()
    ref = fedsim.run(tiny_model, pz, pipe(), rounds=6, engine="scan",
                     chunk_rounds=4, adversary=pv.Adversary(),
                     hooks=[h_ref])
    res = fedsim.run(tiny_model, pz, pipe(), rounds=6, engine="scan",
                     chunk_rounds=4, mesh=mesh8, adversary=pv.Adversary(),
                     hooks=[h_mesh])
    assert res.losses == ref.losses                  # capture stays passive
    np.testing.assert_array_equal(h_mesh.observations()["obs_y"],
                                  h_ref.observations()["obs_y"])
    np.testing.assert_array_equal(h_mesh.payloads(), h_ref.payloads())
