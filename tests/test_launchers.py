"""CLI launchers + serve loop integration tests."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import serve_loop
from repro.models import registry

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_serve_loop_matches_teacher_forcing():
    """Greedy decode through serve_loop is self-consistent: feeding the
    generated tokens back through forward reproduces the same argmax."""
    cfg = registry.get_arch("yi-6b").reduced()
    params = registry.init_params(jax.random.key(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)
    prompts = rng.integers(8, cfg.vocab_size, size=(2, 12)).astype(np.int32)
    out = serve_loop(cfg, params, prompts, gen_steps=6)
    assert out.shape == (2, 18)
    mod = registry.get_module(cfg)
    x = mod.forward(params, cfg, jnp.asarray(out))
    logits = mod.logits_from_hidden(params, x)
    # position t's argmax must equal the token generated at t+1
    for t in range(11, 16):
        pred = np.asarray(jnp.argmax(logits[:, t], axis=-1))
        np.testing.assert_array_equal(pred, out[:, t + 1])


def test_serve_loop_ssm():
    cfg = registry.get_arch("mamba2-370m").reduced()
    params = registry.init_params(jax.random.key(0), cfg, jnp.float32)
    rng = np.random.default_rng(1)
    prompts = rng.integers(8, cfg.vocab_size, size=(2, 8)).astype(np.int32)
    out = serve_loop(cfg, params, prompts, gen_steps=4)
    assert out.shape == (2, 12)


@pytest.mark.slow
def test_train_cli_smoke(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "opt-125m",
         "--reduced", "--rounds", "30", "--clients", "3", "--batch", "4",
         "--seq-len", "16", "--scheme", "perfect", "--n-perturb", "1",
         "--eval-every", "0", "--out", str(tmp_path / "run.json")],
        env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "final_loss" in res.stdout


@pytest.mark.slow
def test_serve_cli_smoke():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "recurrentgemma-2b", "--reduced", "--batch", "2", "--prompt-len",
         "16", "--gen", "4"],
        env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "tok/s" in res.stdout
