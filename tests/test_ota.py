"""OTA channel model: superposition, inversion, noise statistics, faults."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ota


def test_analog_ota_unbiased():
    p = jnp.asarray([1.0, -2.0, 3.0, 0.5, -0.5])
    sigma = jnp.zeros(5)
    vals = []
    for i in range(2000):
        p_hat, _ = ota.analog_ota(p, jnp.float32(2.0), sigma,
                                  jnp.float32(1.0), jax.random.key(i))
        vals.append(float(p_hat))
    vals = np.asarray(vals)
    assert abs(vals.mean() - float(jnp.mean(p))) < 0.02


def test_analog_ota_noise_std_matches_theory():
    """std(p̂) = m/(K·c) with m = sqrt(c²Σσ² + N0)  (Eq. 12)."""
    k, c, n0 = 5, 2.0, 4.0
    sigma = jnp.full((k,), 0.3)
    p = jnp.zeros(k)
    m = np.sqrt(c * c * k * 0.09 + n0)
    expect = m / (k * c)
    vals = [float(ota.analog_ota(p, jnp.float32(c), sigma, jnp.float32(n0),
                                 jax.random.key(i))[0])
            for i in range(4000)]
    assert abs(np.std(vals) - expect) < 0.05 * expect


def test_noiseless_channel_is_exact_mean():
    p = jnp.asarray([1.0, 2.0, 3.0])
    p_hat, k_eff = ota.analog_ota(p, jnp.float32(1.0), jnp.zeros(3),
                                  jnp.float32(0.0), jax.random.key(0))
    assert abs(float(p_hat) - 2.0) < 1e-6
    assert float(k_eff) == 3.0


def test_sign_ota_majority():
    p = jnp.asarray([0.3, 0.7, -0.1, 0.9, 0.2])   # 4 positive vs 1 negative
    p_hat, _ = ota.sign_ota(p, jnp.float32(1.0), jnp.zeros(5),
                            jnp.float32(0.0), jax.random.key(0))
    assert abs(float(p_hat) - 0.6) < 1e-6          # (4 - 1)/5


def test_perfect_baselines():
    p = jnp.asarray([1.0, -3.0, 2.0])
    assert abs(float(ota.perfect_analog(p)) - 0.0) < 1e-6
    assert float(ota.perfect_sign(p)) == 1.0      # 2 positive vs 1 negative


def test_survival_mask_drops_clients():
    p = jnp.asarray([10.0, 20.0, 30.0, 40.0])
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    p_hat, k_eff = ota.analog_ota(p, jnp.float32(1.0), jnp.zeros(4),
                                  jnp.float32(0.0), jax.random.key(0), mask)
    assert float(k_eff) == 2.0
    assert abs(float(p_hat) - 20.0) < 1e-5         # mean of {10, 30}


def test_effective_noise_std():
    m = ota.effective_noise_std(jnp.float32(2.0), jnp.asarray([0.5, 0.5]),
                                jnp.float32(1.0))
    assert abs(float(m) - np.sqrt(4.0 * 0.5 + 1.0)) < 1e-6


def test_channel_draws_reproducible():
    """draw_channels is a deprecated shim over the channel registry; it
    warns, stays seed-stable, and matches the registry draw bit for bit."""
    import pytest

    from repro.channel import RayleighFading
    with pytest.deprecated_call():
        h1 = ota.draw_channels(0, 10, 4)
    with pytest.deprecated_call():
        h3 = ota.draw_channels(1, 10, 4)
    np.testing.assert_array_equal(h1, RayleighFading().realize(0, 10, 4).h)
    assert not np.array_equal(h1, h3)
    assert (h1 > 0).all()
    # Rayleigh with unit average power: E[h²] = 1
    big = RayleighFading().realize(0, 2000, 8).h
    assert abs((big ** 2).mean() - 1.0) < 0.05


def test_analog_ota_csi_gain_factor():
    """Per-client cos θ factors weight the superposition: g ≡ 1 is bitwise
    neutral, g < 1 attenuates the recovered mean."""
    p = jnp.asarray([1.0, 2.0, 3.0])
    ones = jnp.ones(3)
    ref, _ = ota.analog_ota(p, jnp.float32(1.0), jnp.zeros(3),
                            jnp.float32(0.0), jax.random.key(0))
    with_g, _ = ota.analog_ota(p, jnp.float32(1.0), jnp.zeros(3),
                               jnp.float32(0.0), jax.random.key(0), None,
                               ones)
    assert float(ref) == float(with_g)
    half, _ = ota.analog_ota(p, jnp.float32(1.0), jnp.zeros(3),
                             jnp.float32(0.0), jax.random.key(0), None,
                             jnp.full((3,), 0.5))
    assert abs(float(half) - 1.0) < 1e-6          # 0.5 * mean(p)
