"""Channel subsystem: registry, model statistics, trace plumbing, engines.

Three contracts under test:
  1. statistics — each model realizes the distribution it names (unit mean
     power, K-factor moments, AR(1) correlation, outage rate = analytic
     Rayleigh CDF);
  2. specialization — degenerate parameters reproduce the simpler model
     *bitwise* (rician K=0 ≡ rayleigh ≡ legacy draw_channels, ar1 ρ=0 ≡
     rayleigh, phase_err_std=0 ≡ perfect CSI end to end);
  3. engine equivalence — scan and loop stay bit-identical on every
     registered channel model, and outage masks flow into uplink-bit
     accounting.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro import channel as ch
from repro.configs.base import ChannelConfig, TransportConfig
from repro.core import fedsim, ota
from repro.core import transport as tp


def _cc(**kw) -> ChannelConfig:
    return ChannelConfig(n0=1.0, power=100.0, **kw)


# ---------------------------------------------------------------------------
# Registry + composition
# ---------------------------------------------------------------------------

def test_registry_has_builtin_models():
    assert set(ch.available()) >= {"rayleigh", "rician", "static", "ar1",
                                   "geometry", "imperfect_csi", "outage"}
    with pytest.raises(ValueError, match="unknown channel model"):
        ch.get("carrier-pigeon")


def test_models_are_hashable_config_keys():
    assert ch.RicianFading(3.0) == ch.RicianFading(3.0)
    assert hash(ch.AR1Correlated(0.5)) == hash(ch.AR1Correlated(0.5))
    assert ch.RicianFading(3.0) != ch.RicianFading(4.0)
    wrapped = ch.ImperfectCSI(base=ch.RicianFading(2.0), phase_err_std=0.1)
    assert wrapped == ch.ImperfectCSI(base=ch.RicianFading(2.0),
                                      phase_err_std=0.1)


def test_from_config_composes_wrapper_stack():
    model = ch.from_config(_cc(model="rician", rician_k=7.0,
                               phase_err_std=0.2, outage_db=-12.0,
                               cell_radius=200.0))
    assert isinstance(model, ch.OutageModel)
    assert model.threshold_db == -12.0
    assert isinstance(model.base, ch.ImperfectCSI)
    assert isinstance(model.base.base, ch.PathLossGeometry)
    assert isinstance(model.base.base.base, ch.RicianFading)
    assert model.base.base.base.k_factor == 7.0
    # legacy `fading` string still resolves when `model` is unset
    assert isinstance(ch.from_config(_cc(fading="static")),
                      ch.StaticChannel)


def test_wrappers_rejected_as_base_model():
    """Selecting a wrapper by name would silently ignore its config fields
    and double-wrap it — from_config must refuse and point at the config
    fields that compose it."""
    for name in ("geometry", "imperfect_csi", "outage"):
        with pytest.raises(ValueError, match="is a wrapper"):
            ch.from_config(_cc(model=name))


def test_empty_round_readmission_respects_fault_mask(make_pz):
    """When outage x faults zero a round, the re-admitted client must be
    fault-surviving — never a crashed one, however strong its channel."""
    from repro.core import engine as eng
    from repro.core.power_control import PowerSchedule

    pz = make_pz(rounds=4, n_clients=2, scheme="perfect")
    sched = PowerSchedule(c=np.ones(4), sigma=np.zeros((4, 2)),
                          scheme="perfect", n0=0.0)
    # hand-built trace: client 0 has the STRONG channel but the fault
    # model crashed it; client 1 is weak and in outage
    ctrace = ch.ChannelTrace(
        h=np.asarray([[9.0, 1.0]] * 4),
        participation=np.asarray([[1.0, 0.0]] * 4, np.float32))

    class KillClient0:
        def survival_mask(self, t):
            return np.asarray([0.0, 1.0], np.float32)  # client 0 crashed

    trace = eng.build_trace(sched, pz, 0, 4, fault=KillClient0(),
                            channel=ctrace)
    # combined mask is all-zero; re-admission must pick client 1 (fault-
    # surviving, outage notwithstanding) — a naive argmax over h would
    # resurrect the crashed-but-strong client 0
    np.testing.assert_array_equal(np.asarray(trace.ctl["mask"]),
                                  np.asarray([[0.0, 1.0]] * 4, np.float32))


def test_trace_shape_validation():
    with pytest.raises(ValueError, match="shapes disagree"):
        ch.ChannelTrace(h=np.ones((4, 3)), phase=np.zeros((4, 2)))


# ---------------------------------------------------------------------------
# Statistics (fixed seed)
# ---------------------------------------------------------------------------

def test_rayleigh_unit_mean_power():
    trace = ch.RayleighFading().realize(0, 4000, 8)
    assert abs((trace.h ** 2).mean() - 1.0) < 0.05
    np.testing.assert_allclose(trace.mean_power(), 1.0, atol=0.1)
    assert (trace.phase == 0).all() and (trace.participation == 1).all()


def test_rician_k_factor_moments():
    """E[|h|²] = 1 for every K, Var(|h|²) = (2K+1)/(K+1)² (noncentral
    χ²₂), and larger K concentrates the fade."""
    for k_factor in (0.5, 3.0, 10.0):
        trace = ch.RicianFading(k_factor).realize(1, 6000, 4)
        power = trace.h ** 2
        assert abs(power.mean() - 1.0) < 0.05, k_factor
        var_expect = (2.0 * k_factor + 1.0) / (k_factor + 1.0) ** 2
        assert abs(power.var() - var_expect) < 0.12 * var_expect, k_factor
    assert ch.RicianFading(10.0).realize(1, 6000, 4).h.var() < \
        ch.RicianFading(0.5).realize(1, 6000, 4).h.var()


def test_ar1_lag1_autocorrelation():
    """Power autocorrelation at lag 1 ≈ ρ² (complex-Gaussian AR(1))."""
    for rho in (0.0, 0.5, 0.9):
        trace = ch.AR1Correlated(rho).realize(2, 8000, 4)
        power = trace.h ** 2
        x, y = power[:-1].ravel(), power[1:].ravel()
        corr = np.corrcoef(x, y)[0, 1]
        assert abs(corr - rho ** 2) < 0.05, rho
        assert abs(power.mean() - 1.0) < 0.05, rho   # stationary unit power


def test_outage_rate_matches_rayleigh_cdf():
    """P(outage) = P(|h|² < τ) = 1 - exp(-τ) for unit-power Rayleigh."""
    for thr_db in (-20.0, -10.0, -3.0):
        model = ch.OutageModel(base=ch.RayleighFading(),
                               threshold_db=thr_db)
        trace = model.realize(3, 6000, 5)
        tau = 10.0 ** (thr_db / 10.0)
        expect = 1.0 - np.exp(-tau)
        assert abs(trace.outage_rate() - expect) < 0.01 + 0.1 * expect, \
            thr_db
        # never a fully-silent round (strongest client re-admitted)
        assert (trace.participation.sum(axis=1) >= 1).all()


def test_shadowing_sigma_zero_is_bitwise_neutral():
    """shadow_std_db=0 must not even consume the shadowing RNG stream —
    gains (and the full realized trace) stay bitwise the historical
    wrapper's."""
    plain = ch.PathLossGeometry(base=ch.RayleighFading(), cell_radius=150.0)
    shadow0 = ch.PathLossGeometry(base=ch.RayleighFading(),
                                  cell_radius=150.0, shadow_std_db=0.0,
                                  shadow_corr=0.9)
    np.testing.assert_array_equal(plain.client_gains(4, 6),
                                  shadow0.client_gains(4, 6))
    np.testing.assert_array_equal(plain.realize(4, 50, 6).h,
                                  shadow0.realize(4, 50, 6).h)


def test_shadowing_changes_gains_and_is_seeded():
    base = ch.PathLossGeometry(base=ch.RayleighFading(), cell_radius=150.0)
    sh = ch.PathLossGeometry(base=ch.RayleighFading(), cell_radius=150.0,
                             shadow_std_db=8.0, shadow_corr=0.5)
    g0, gs = base.client_gains(4, 6), sh.client_gains(4, 6)
    assert not np.array_equal(g0, gs)
    assert abs(gs.mean() - 1.0) < 1e-12             # still normalized
    np.testing.assert_array_equal(gs, sh.client_gains(4, 6))  # seeded
    assert not np.array_equal(gs, sh.client_gains(5, 6))


def test_shadowing_correlation_shrinks_spread():
    """rho=1 is a common dB offset to every client — the mean-1
    normalization removes it entirely, so fully-correlated shadowing
    reproduces the unshadowed gains; rho=0 adds genuine per-client
    spread."""
    plain = ch.PathLossGeometry(base=ch.RayleighFading(), cell_radius=150.0)
    full = ch.PathLossGeometry(base=ch.RayleighFading(), cell_radius=150.0,
                               shadow_std_db=8.0, shadow_corr=1.0)
    indep = ch.PathLossGeometry(base=ch.RayleighFading(), cell_radius=150.0,
                                shadow_std_db=8.0, shadow_corr=0.0)
    g_plain, g_full = plain.client_gains(4, 64), full.client_gains(4, 64)
    np.testing.assert_allclose(g_full, g_plain, rtol=1e-12)
    g_indep = indep.client_gains(4, 64)
    spread = lambda g: np.std(10.0 * np.log10(g))
    assert spread(g_indep) > spread(g_plain)


def test_shadowing_config_plumbing():
    model = ch.from_config(_cc(cell_radius=150.0, shadow_std_db=6.0,
                               shadow_corr=0.3))
    assert isinstance(model, ch.PathLossGeometry)
    assert model.shadow_std_db == 6.0 and model.shadow_corr == 0.3
    with pytest.raises(ValueError, match="cell_radius == 0"):
        ch.from_config(_cc(shadow_std_db=6.0))
    with pytest.raises(ValueError, match="shadow_corr"):
        ch.PathLossGeometry(base=ch.RayleighFading(), cell_radius=150.0,
                            shadow_std_db=6.0,
                            shadow_corr=1.5).client_gains(0, 4)


def test_geometry_breaks_unit_power_symmetry():
    model = ch.PathLossGeometry(base=ch.RayleighFading(), cell_radius=150.0)
    trace = model.realize(4, 4000, 6)
    gains = model.client_gains(4, 6)
    assert abs(gains.mean() - 1.0) < 1e-12          # normalized
    assert gains.max() / gains.min() > 3.0           # genuinely heterogeneous
    np.testing.assert_allclose(trace.mean_power(), gains, rtol=0.15)
    # placement is a function of the seed: same seed, same cell layout
    np.testing.assert_array_equal(gains, model.client_gains(4, 6))
    assert not np.array_equal(gains, model.client_gains(5, 6))


def test_imperfect_csi_factors():
    model = ch.ImperfectCSI(base=ch.RayleighFading(), phase_err_std=0.3)
    trace = model.realize(5, 2000, 4)
    base = ch.RayleighFading().realize(5, 2000, 4)
    np.testing.assert_array_equal(trace.h, base.h)   # magnitudes untouched
    assert abs(trace.phase.std() - 0.3) < 0.02
    assert (trace.csi <= 1.0).all()
    # E[cos θ] = exp(-σ²/2) for θ ~ N(0, σ²)
    assert abs(trace.csi.mean() - np.exp(-0.045)) < 0.01
    assert np.iscomplexobj(trace.gain)
    np.testing.assert_allclose(np.abs(trace.gain), trace.h, rtol=1e-12)


# ---------------------------------------------------------------------------
# Bitwise specializations
# ---------------------------------------------------------------------------

def test_rician_k0_and_ar1_rho0_are_rayleigh_bitwise():
    ray = ch.RayleighFading().realize(7, 64, 5).h
    np.testing.assert_array_equal(ch.RicianFading(0.0).realize(7, 64, 5).h,
                                  ray)
    np.testing.assert_array_equal(ch.AR1Correlated(0.0).realize(7, 64, 5).h,
                                  ray)


def test_draw_channels_shim_warns_and_is_bit_identical():
    """The legacy ota.draw_channels routes through the registry and stays
    bit-identical for rayleigh/static, so PR-1/PR-2 trajectories still
    reproduce."""
    with pytest.deprecated_call():
        legacy_ray = ota.draw_channels(0, 32, 4, "rayleigh")
    with pytest.deprecated_call():
        legacy_static = ota.draw_channels(0, 32, 4, "static")
    np.testing.assert_array_equal(
        legacy_ray, ch.RayleighFading().realize(0, 32, 4).h)
    np.testing.assert_array_equal(
        legacy_static, ch.StaticChannel().realize(0, 32, 4).h)
    # and the historical inline formula, re-derived here as the oracle
    rng = np.random.default_rng(0)
    re = rng.normal(size=(32, 4)) / np.sqrt(2.0)
    im = rng.normal(size=(32, 4)) / np.sqrt(2.0)
    np.testing.assert_array_equal(legacy_ray, np.sqrt(re * re + im * im))
    with pytest.raises(ValueError, match="unknown fading"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            ota.draw_channels(0, 4, 2, "tropospheric-scatter")


def test_phase_err_zero_bit_identical_to_perfect_csi(tiny_model, make_pz,
                                                     make_pipeline):
    """An ImperfectCSI wrapper with phase_err_std=0 draws θ ≡ 0: running
    the *wrapped* model end to end (injected via channel_model=, the same
    path any user-built wrapper stack takes) must equal the unwrapped
    perfect-CSI run bitwise, on both engines."""
    pz = dataclasses.replace(
        make_pz(rounds=6), channel=_cc(),
        transport=TransportConfig("analog", "solution"))
    wrapped = ch.ImperfectCSI(base=ch.RayleighFading(), phase_err_std=0.0)
    tr = wrapped.realize(0, 6, 5)
    np.testing.assert_array_equal(tr.csi, np.ones_like(tr.csi))
    for engine in ("loop", "scan"):
        res_p = fedsim.run(tiny_model, pz, make_pipeline(),
                           rounds=6, engine=engine, chunk_rounds=4)
        res_w = fedsim.run(tiny_model, pz, make_pipeline(), rounds=6,
                           engine=engine, chunk_rounds=4,
                           channel_model=wrapped)
        assert res_p.losses == res_w.losses, engine
        assert res_p.p_hats == res_w.p_hats, engine
        assert res_p.privacy_spent == res_w.privacy_spent, engine


def test_imperfect_csi_attenuates_not_crashes(tiny_model, make_pz,
                                              make_pipeline):
    """Nonzero phase error changes the trajectory (the h_k α_k = c
    assumption really is consumed from the trace) but stays finite."""
    base = dataclasses.replace(
        make_pz(rounds=6), transport=TransportConfig("analog", "solution"))
    res_perfect = fedsim.run(tiny_model, dataclasses.replace(
        base, channel=_cc()), make_pipeline(), rounds=6)
    res_csi = fedsim.run(tiny_model, dataclasses.replace(
        base, channel=_cc(phase_err_std=0.5)), make_pipeline(), rounds=6)
    assert np.isfinite(res_csi.losses).all()
    assert res_csi.p_hats != res_perfect.p_hats


# ---------------------------------------------------------------------------
# Engine bit-identity on every registered model + outage accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cc", [
    _cc(model="rician", rician_k=4.0),
    _cc(model="ar1", ar1_rho=0.8),
    _cc(model="static"),
    _cc(model="rayleigh", phase_err_std=0.2),
    _cc(model="rayleigh", outage_db=-6.0),
    _cc(model="rayleigh", cell_radius=150.0),
], ids=["rician", "ar1", "static", "imperfect_csi", "outage", "geometry"])
def test_scan_loop_bit_identical_on_channel_models(tiny_model, make_pz,
                                                   make_pipeline, cc):
    pz = dataclasses.replace(
        make_pz(rounds=7), channel=cc,
        transport=TransportConfig("analog", "solution"))
    res_loop = fedsim.run(tiny_model, pz, make_pipeline(), rounds=7,
                          engine="loop")
    res_scan = fedsim.run(tiny_model, pz, make_pipeline(), rounds=7,
                          engine="scan", chunk_rounds=3)
    assert res_loop.losses == res_scan.losses
    assert res_loop.p_hats == res_scan.p_hats
    assert res_loop.privacy_spent == res_scan.privacy_spent
    assert res_loop.uplink_bits == res_scan.uplink_bits
    assert np.isfinite(res_loop.losses).all()


def test_outage_mask_reduces_uplink_bits(tiny_model, make_pz,
                                         make_pipeline):
    """Clients in deep fade transmit nothing and are billed nothing: the
    run's uplink_bits equals payload x Σ_t K_participating(t), strictly
    below the full-participation bill."""
    rounds = 10
    base = dataclasses.replace(
        make_pz(rounds=rounds),
        transport=TransportConfig("analog", "solution"))
    pz = dataclasses.replace(base, channel=_cc(outage_db=-3.0))
    res = fedsim.run(tiny_model, pz, make_pipeline(), rounds=rounds)
    trace = ch.realize_from_config(pz.channel, pz.seed ^ 0xC4A7,
                                   pz.rounds, pz.n_clients)
    expect_client_rounds = int(trace.participation[:rounds].sum())
    payload = tp.resolve(pz).payload_bits(pz, tiny_model.param_count())
    assert res.uplink_bits == payload * expect_client_rounds
    full = fedsim.run(tiny_model, dataclasses.replace(base, channel=_cc()),
                      make_pipeline(), rounds=rounds)
    assert res.uplink_bits < full.uplink_bits
    # k_eff metric saw the stragglers too
    assert expect_client_rounds < rounds * pz.n_clients


def test_outage_composes_with_fault_masks(tiny_model, make_pz,
                                          make_pipeline):
    """Outage participation multiplies the FaultModel survival mask, and
    the combined mask still never empties a round — on both engines,
    identically."""
    from repro.runtime.fault import FaultModel
    pz = dataclasses.replace(
        make_pz(rounds=8), channel=_cc(outage_db=-3.0),
        transport=TransportConfig("analog", "solution"))
    results = {}
    for engine in ("loop", "scan"):
        results[engine] = fedsim.run(
            tiny_model, pz, make_pipeline(), rounds=8, engine=engine,
            chunk_rounds=5,
            fault=FaultModel(pz.n_clients, dropout_p=0.4, seed=3))
    assert results["loop"].losses == results["scan"].losses
    assert results["loop"].uplink_bits == results["scan"].uplink_bits
    assert np.isfinite(results["loop"].losses).all()


# ---------------------------------------------------------------------------
# Property tests over the model parameter space (slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_hypothesis_unit_mean_power_across_models():
    """Every small-scale model keeps E[|h|²] = 1 across its parameter
    space — the normalization the power-control solves assume."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 10_000), st.floats(0.0, 15.0),
           st.floats(0.0, 0.97))
    def run(seed, k_factor, rho):
        for model in (ch.RicianFading(k_factor), ch.AR1Correlated(rho)):
            power = model.realize(seed, 3000, 4).h ** 2
            assert abs(power.mean() - 1.0) < 0.08, model

    run()


@pytest.mark.slow
def test_hypothesis_outage_rate_tracks_cdf():
    """Outage rate stays within sampling error of 1 - exp(-τ) for any
    threshold, and participation never empties a round."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 10_000), st.floats(-25.0, 0.0))
    def run(seed, thr_db):
        trace = ch.OutageModel(base=ch.RayleighFading(),
                               threshold_db=thr_db).realize(seed, 3000, 5)
        tau = 10.0 ** (thr_db / 10.0)
        expect = 1.0 - np.exp(-tau)
        assert abs(trace.outage_rate() - expect) < 0.02 + 0.12 * expect
        assert (trace.participation.sum(axis=1) >= 1).all()

    run()


# ---------------------------------------------------------------------------
# Doppler-parameterized AR(1): physical mobility via Jakes' J0(2π f_D τ)
# ---------------------------------------------------------------------------

def test_bessel_j0_reference_points():
    # J0(0) = 1 and the first zero at x ≈ 2.404826 (A&S |err| < 5e-8)
    assert ch.bessel_j0(0.0) == pytest.approx(1.0, abs=1e-7)
    assert ch.bessel_j0(2.404826) == pytest.approx(0.0, abs=1e-5)
    assert ch.bessel_j0(1.0) == pytest.approx(0.7651976866, abs=1e-6)
    assert ch.bessel_j0(5.0) == pytest.approx(-0.1775967713, abs=1e-6)


def test_bessel_j0_against_scipy():
    sp = pytest.importorskip("scipy.special")
    for x in np.linspace(0.0, 20.0, 101):
        assert ch.bessel_j0(x) == pytest.approx(float(sp.j0(x)), abs=2e-6)


def test_jakes_rho_physical_regimes():
    # pedestrian: f_D·τ ≪ 1 → nearly fully correlated fading
    assert ch.jakes_rho(5.0, 1e-3) > 0.99
    # vehicular at long rounds: correlation decays
    assert ch.jakes_rho(100.0, 1e-3) < ch.jakes_rho(10.0, 1e-3)
    # past the first J0 zero the AR(1) surrogate clamps to i.i.d.
    assert ch.jakes_rho(500.0, 1e-3) == 0.0
    # always a valid AR(1) correlation
    for fd in (0.0, 1.0, 50.0, 1e4):
        rho = ch.jakes_rho(fd, 1e-3)
        assert 0.0 <= rho < 1.0
        ch.AR1Correlated(rho=rho).realize(0, 4, 2)   # accepted by the model
    with pytest.raises(ValueError):
        ch.jakes_rho(-1.0, 1e-3)
    with pytest.raises(ValueError):
        ch.jakes_rho(10.0, 0.0)


def test_doppler_config_maps_to_rho_and_is_bitwise_neutral_unset():
    # doppler set: from_config derives ρ via Jakes, ignoring ar1_rho
    cc = _cc(model="ar1", ar1_rho=0.3, doppler_hz=10.0,
             round_duration_s=1e-3)
    model = ch.from_config(cc)
    assert isinstance(model, ch.AR1Correlated)
    assert model.rho == ch.jakes_rho(10.0, 1e-3)
    assert model.rho != 0.3
    # doppler unset: the raw-ρ path is bitwise what it always was
    cc0 = _cc(model="ar1", ar1_rho=0.3)
    m0 = ch.from_config(cc0)
    assert m0 == ch.AR1Correlated(rho=0.3)
    np.testing.assert_array_equal(
        m0.realize(7, 16, 3).h, ch.AR1Correlated(rho=0.3).realize(7, 16, 3).h)


def test_doppler_rejected_on_non_ar1_models():
    """doppler_hz on a model that cannot consume it is an error, not a
    silently-ignored knob (same convention as the wrapper guard)."""
    for model in (None, "rayleigh", "rician", "static"):
        with pytest.raises(ValueError, match="doppler_hz"):
            ch.from_config(_cc(model=model, doppler_hz=50.0))
    # ar1 consumes it
    assert ch.from_config(_cc(model="ar1", doppler_hz=50.0)).rho == \
        ch.jakes_rho(50.0, 1e-3)
