"""Fault tolerance: dropout/straggler masks, elasticity, training under faults."""
import numpy as np

from repro.runtime.fault import (ElasticSchedule, FaultModel, combined_mask)


def test_no_faults_full_mask():
    mask = combined_mask(0, None, None, n_clients=5)
    assert mask.sum() == 5


def test_dropout_rate():
    fm = FaultModel(n_clients=100, dropout_p=0.3, seed=0)
    rates = [fm.survival_mask(t).mean() for t in range(200)]
    assert abs(np.mean(rates) - 0.7) < 0.03


def test_fault_trace_reproducible():
    a = FaultModel(n_clients=8, dropout_p=0.2, straggler_p=0.1, seed=7)
    b = FaultModel(n_clients=8, dropout_p=0.2, straggler_p=0.1, seed=7)
    for t in range(50):
        assert np.array_equal(a.survival_mask(t), b.survival_mask(t))


def test_hard_failure_and_repair():
    fm = FaultModel(n_clients=4, mtbf_rounds=5.0, repair_rounds=3, seed=1)
    masks = np.stack([fm.survival_mask(t) for t in range(100)])
    # someone fails eventually, and everyone comes back eventually
    assert masks.min() == 0.0
    assert (masks.sum(axis=0) > 50).all()


def test_never_empty_round():
    fm = FaultModel(n_clients=3, dropout_p=0.999, seed=2)
    for t in range(50):
        assert fm.survival_mask(t).sum() >= 1.0


def test_elastic_schedule():
    es = ElasticSchedule(n_clients=8, events=((10, 4), (20, 6)))
    assert es.active_k(0) == 8
    assert es.active_k(10) == 4
    assert es.active_k(25) == 6
    assert es.membership_mask(12).sum() == 4


def test_training_survives_faults():
    """ZO fine-tuning keeps making progress with 20% dropout + elasticity."""
    import jax.numpy as jnp
    from repro.configs.base import (ModelConfig, PairZeroConfig,
                                    PowerControlConfig, ZOConfig)
    from repro.core import fedsim
    from repro.data.pipeline import FederatedPipeline
    from repro.data.tasks import TaskSpec

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=48,
                      n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=64,
                      head_dim=12)
    pz = PairZeroConfig(variant="analog", n_clients=5,
                        zo=ZOConfig(mu=1e-3, lr=5e-3, clip_gamma=5.0,
                                    n_perturb=2),
                        power=PowerControlConfig(scheme="perfect"))
    pipe = FederatedPipeline(task="sst2", spec=TaskSpec("sst2", 64, 16),
                             n_clients=5, per_client_batch=4, seed=0)
    fault = FaultModel(n_clients=5, dropout_p=0.2, straggler_p=0.05, seed=3)
    elastic = ElasticSchedule(n_clients=5, events=((60, 3), (120, 5)))
    res = fedsim.run(cfg, pz, pipe, rounds=200, fault=fault,
                     elastic=elastic)
    assert np.isfinite(res.losses).all()
    assert np.mean(res.losses[-20:]) < np.mean(res.losses[:20])
