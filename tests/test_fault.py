"""Fault tolerance: dropout/straggler masks, elasticity, training under faults."""
import numpy as np

from repro.runtime.fault import (ElasticSchedule, FaultModel, combined_mask)


def test_no_faults_full_mask():
    mask = combined_mask(0, None, None, n_clients=5)
    assert mask.sum() == 5


def test_faultmodel_validation():
    import pytest
    with pytest.raises(ValueError, match="n_clients"):
        FaultModel(n_clients=0)
    with pytest.raises(ValueError, match="dropout_p"):
        FaultModel(n_clients=4, dropout_p=1.2)
    with pytest.raises(ValueError, match="straggler_p"):
        FaultModel(n_clients=4, straggler_p=-0.1)
    # each probability is legal alone but their sum exceeds 1: the
    # per-round keep-probability would go negative
    with pytest.raises(ValueError, match="dropout_p . straggler_p"):
        FaultModel(n_clients=4, dropout_p=0.7, straggler_p=0.5)


def test_combined_mask_requires_population():
    import pytest
    with pytest.raises(ValueError, match="n_clients"):
        combined_mask(0, None, None)
    # any of the three sources pins K without the explicit arg
    es = ElasticSchedule(n_clients=6)
    assert combined_mask(0, None, es).shape == (6,)


def test_dropout_rate():
    fm = FaultModel(n_clients=100, dropout_p=0.3, seed=0)
    rates = [fm.survival_mask(t).mean() for t in range(200)]
    assert abs(np.mean(rates) - 0.7) < 0.03


def test_fault_trace_reproducible():
    a = FaultModel(n_clients=8, dropout_p=0.2, straggler_p=0.1, seed=7)
    b = FaultModel(n_clients=8, dropout_p=0.2, straggler_p=0.1, seed=7)
    for t in range(50):
        assert np.array_equal(a.survival_mask(t), b.survival_mask(t))


def test_hard_failure_and_repair():
    fm = FaultModel(n_clients=4, mtbf_rounds=5.0, repair_rounds=3, seed=1)
    masks = np.stack([fm.survival_mask(t) for t in range(100)])
    # someone fails eventually, and everyone comes back eventually
    assert masks.min() == 0.0
    assert (masks.sum(axis=0) > 50).all()


def test_never_empty_round():
    fm = FaultModel(n_clients=3, dropout_p=0.999, seed=2)
    for t in range(50):
        assert fm.survival_mask(t).sum() >= 1.0


def test_elastic_schedule():
    es = ElasticSchedule(n_clients=8, events=((10, 4), (20, 6)))
    assert es.active_k(0) == 8
    assert es.active_k(10) == 4
    assert es.active_k(25) == 6
    assert es.membership_mask(12).sum() == 4


def test_elastic_event_boundaries_through_scan():
    """Membership flips land on the exact event round even when a scan
    chunk spans the event — the precomputed trace replays the per-round
    loop's masks row for row."""
    from repro.configs.base import (ModelConfig, PairZeroConfig,
                                    PowerControlConfig, ZOConfig)
    from repro.core import engine as eng
    from repro.core import power_control as pc

    es = ElasticSchedule(n_clients=5, events=((4, 3), (8, 5)))
    pz = PairZeroConfig(variant="analog", n_clients=5, rounds=10,
                        zo=ZOConfig(mu=1e-3, lr=5e-3, clip_gamma=5.0,
                                    n_perturb=1),
                        power=PowerControlConfig(scheme="perfect"))
    sched = pc.PowerSchedule(c=np.ones(10), sigma=np.zeros((10, 5)),
                             scheme="perfect", n0=0.0)
    # chunks [0,6) and [6,10) both straddle an event round (4 and 8)
    tr_a = eng.build_trace(sched, pz, 0, 6, elastic=es)
    tr_b = eng.build_trace(sched, pz, 6, 10, elastic=es)
    masks = np.concatenate([np.asarray(tr_a.ctl["mask"]),
                            np.asarray(tr_b.ctl["mask"])])
    expect = np.stack([es.membership_mask(t) for t in range(10)])
    np.testing.assert_array_equal(masks, expect)
    assert masks[3].sum() == 5 and masks[4].sum() == 3   # flip AT round 4
    assert masks[7].sum() == 3 and masks[8].sum() == 5   # and back at 8


def test_training_survives_faults():
    """ZO fine-tuning keeps making progress with 20% dropout + elasticity."""
    import jax.numpy as jnp
    from repro.configs.base import (ModelConfig, PairZeroConfig,
                                    PowerControlConfig, ZOConfig)
    from repro.core import fedsim
    from repro.data.pipeline import FederatedPipeline
    from repro.data.tasks import TaskSpec

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=48,
                      n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=64,
                      head_dim=12)
    pz = PairZeroConfig(variant="analog", n_clients=5,
                        zo=ZOConfig(mu=1e-3, lr=5e-3, clip_gamma=5.0,
                                    n_perturb=2),
                        power=PowerControlConfig(scheme="perfect"))
    pipe = FederatedPipeline(task="sst2", spec=TaskSpec("sst2", 64, 16),
                             n_clients=5, per_client_batch=4, seed=0)
    fault = FaultModel(n_clients=5, dropout_p=0.2, straggler_p=0.05, seed=3)
    elastic = ElasticSchedule(n_clients=5, events=((60, 3), (120, 5)))
    res = fedsim.run(cfg, pz, pipe, rounds=200, fault=fault,
                     elastic=elastic)
    assert np.isfinite(res.losses).all()
    assert np.mean(res.losses[-20:]) < np.mean(res.losses[:20])
