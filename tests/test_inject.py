"""Fault injection + bounded retry: determinism, recovery, degradation.

The chaos layer's contract: faults fire at site ENTRY as a pure function
of (seed, site, invocation index), recoveries are span-instrumented and
counted, and a recovered run finishes bit-identical to an undisturbed
one — the retried work replays from a clean slate.
"""
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core import engine as eng
from repro.core import fedsim
from repro.obs import spans as ob
from repro.runtime import inject as inj


# ---------------------------------------------------------------------------
# FaultInjector: spec parsing, determinism, selectors
# ---------------------------------------------------------------------------

def test_from_specs_parsing():
    injector = inj.FaultInjector.from_specs(
        ["dispatch:exception:@2,5", "ckpt_write:torn_write",
         "chunk_prep:delay:0.25"])
    assert injector.faults["dispatch"].at == (2, 5)
    assert injector.faults["ckpt_write"].p == 1.0
    assert injector.faults["chunk_prep"].p == 0.25
    with pytest.raises(ValueError, match="spec"):
        inj.FaultInjector.from_specs(["dispatch"])
    with pytest.raises(ValueError, match="site"):
        inj.FaultInjector.from_specs(["warp_core:exception"])
    with pytest.raises(ValueError, match="mode"):
        inj.FaultInjector.from_specs(["dispatch:segfault"])
    with pytest.raises(ValueError, match="probability"):
        inj.FaultInjector.from_specs(["dispatch:exception:1.5"])


def test_exact_invocation_selector():
    injector = inj.FaultInjector.from_specs(["dispatch:exception:@1,3"])
    fired = []
    for i in range(5):
        try:
            injector.fire("dispatch")
            fired.append(False)
        except inj.InjectedFault:
            fired.append(True)
    assert fired == [False, True, False, True, False]
    assert injector.fired["dispatch"] == 2
    assert injector.counts["dispatch"] == 5


def test_probabilistic_fires_are_deterministic():
    """Same (seed, site, invocation) => same decision, independent of any
    other site's history or process state."""
    a = inj.FaultInjector.from_specs(["dispatch:delay:0.3",
                                      "chunk_prep:delay:0.4"], seed=7)
    b = inj.FaultInjector.from_specs(["dispatch:delay:0.3"], seed=7)
    seq_a = [a.fire("dispatch") for _ in range(50)]
    for _ in range(13):
        a.fire("chunk_prep")                  # interleaved other-site fires
    b_seq = [b.fire("dispatch") for _ in range(50)]
    assert seq_a == b_seq
    assert 0 < seq_a.count("delay") < 50      # p=0.3 actually does both
    c = inj.FaultInjector.from_specs(["dispatch:delay:0.3"], seed=8)
    assert [c.fire("dispatch") for _ in range(50)] != seq_a


def test_unarmed_site_never_fires():
    injector = inj.FaultInjector.from_specs(["dispatch:exception"])
    assert injector.fire("ckpt_write") is None
    assert injector.counts["ckpt_write"] == 1
    assert injector.fired == {}


# ---------------------------------------------------------------------------
# with_retries: spans, counters, exhaustion
# ---------------------------------------------------------------------------

def test_with_retries_recovers_and_instruments():
    injector = inj.FaultInjector.from_specs(["dispatch:exception:@0"])
    tracer = ob.Tracer()
    retries = {}
    calls = []
    out = inj.with_retries(lambda: calls.append(1) or "ok", site="dispatch",
                           attempts=3, injector=injector, tracer=tracer,
                           backoff_s=0.0, retries=retries)
    assert out == "ok"
    assert calls == [1]                       # fault fired BEFORE fn ran
    assert retries == {"dispatch": 1}
    spans = [e for e in tracer.spans() if e["name"] == "retry"]
    assert len(spans) == 1
    assert spans[0]["args"]["site"] == "dispatch"
    assert spans[0]["args"]["error"] == "InjectedFault"


def test_with_retries_exhausts_and_raises():
    injector = inj.FaultInjector.from_specs(["dispatch:exception"])  # always
    retries = {}
    with pytest.raises(inj.InjectedFault):
        inj.with_retries(lambda: "never", site="dispatch", attempts=3,
                         injector=injector, backoff_s=0.0, retries=retries)
    assert retries == {"dispatch": 2}         # attempts-1 re-tries


def test_with_retries_plain_call_without_injector():
    assert inj.with_retries(lambda: 42, site="dispatch") == 42
    with pytest.raises(KeyError):
        inj.with_retries(lambda: {}["x"], site="dispatch", attempts=2,
                         backoff_s=0.0)


# ---------------------------------------------------------------------------
# ChunkPrefetcher degradation: worker death -> inline re-run, once
# ---------------------------------------------------------------------------

def test_prefetcher_degrades_to_inline_rerun():
    injector = inj.FaultInjector.from_specs(["chunk_prep:exception:@1"])
    tracer = ob.Tracer()
    prepared = []
    pf = eng.ChunkPrefetcher(lambda a, b: prepared.append((a, b)) or (a, b),
                             [(0, 2), (2, 4), (4, 6)], overlap=True,
                             tracer=tracer, injector=injector)
    out = []
    for i in range(3):
        pf.kick(i)                 # chunk i's prep on the worker thread
        out.append(pf.get(i))
    pf.close()
    # invocation 1 = chunk 1's kicked prep died; re-ran inline (invocation
    # 2, clean) and every payload still arrived in order
    assert out == [(0, 2), (2, 4), (4, 6)]
    assert pf.degraded == 1
    assert prepared == [(0, 2), (2, 4), (4, 6)]
    names = [e["name"] for e in tracer.spans()]
    assert names.count("prefetch_degraded") == 1


def test_prefetcher_second_failure_propagates():
    injector = inj.FaultInjector.from_specs(["chunk_prep:exception"])
    pf = eng.ChunkPrefetcher(lambda a, b: (a, b), [(0, 2)], overlap=True,
                             injector=injector)
    pf.kick(0)
    with pytest.raises(inj.InjectedFault):
        pf.get(0)                  # inline re-run also dies -> propagate
    pf.close()


# ---------------------------------------------------------------------------
# AsyncCheckpointer under injection: retry, keep-last-good, torn writes
# ---------------------------------------------------------------------------

@pytest.fixture
def params():
    import jax.numpy as jnp
    return {"w": jnp.arange(8.0), "b": jnp.ones((3,))}


def test_ckpt_write_retry_then_success(tmp_path, params):
    injector = inj.FaultInjector.from_specs(["ckpt_write:exception:@0"])
    tracer = ob.Tracer()
    acp = ckpt.AsyncCheckpointer(str(tmp_path), tracer=tracer,
                                 injector=injector)
    acp.save(1, params, extra={})
    acp.wait()
    assert acp.write_failures == 0
    assert acp.retries == {"ckpt_write": 1}
    assert ckpt.latest_valid(str(tmp_path)).endswith("step_00000001")
    assert any(e["name"] == "retry" for e in tracer.spans())


def test_ckpt_write_keep_last_good(tmp_path, params):
    """Exhausting write retries swallows the failure and keeps the last
    good checkpoint — a flaky filesystem must not abort training."""
    injector = inj.FaultInjector.from_specs(["ckpt_write:exception:@1,2"])
    acp = ckpt.AsyncCheckpointer(str(tmp_path), injector=injector,
                                 write_retries=2)
    acp.save(1, params, extra={})             # invocation 0: clean
    acp.wait()
    acp.save(2, params, extra={})             # invocations 1,2: both die
    acp.wait()
    assert acp.write_failures == 1
    assert ckpt.latest_valid(str(tmp_path)).endswith("step_00000001")


def test_ckpt_snapshot_failure_skips_boundary(tmp_path, params):
    injector = inj.FaultInjector.from_specs(["ckpt_snapshot:exception:@0"])
    tracer = ob.Tracer()
    acp = ckpt.AsyncCheckpointer(str(tmp_path), tracer=tracer,
                                 injector=injector)
    acp.save(1, params, extra={})             # boundary skipped, no raise
    acp.wait()
    acp.save(2, params, extra={})             # next boundary lands
    acp.wait()
    assert acp.snapshot_failures == 1
    assert ckpt.latest(str(tmp_path)).endswith("step_00000002")
    assert any(e["name"] == "ckpt_skipped" for e in tracer.events())


def test_torn_write_detected_and_skipped(tmp_path, params):
    """torn_write truncates the just-written npz: naive latest() still
    points at it, the CRC walk falls back past it."""
    injector = inj.FaultInjector.from_specs(["ckpt_write:torn_write:@1"])
    acp = ckpt.AsyncCheckpointer(str(tmp_path), injector=injector)
    acp.save(1, params, extra={})
    acp.wait()
    acp.save(2, params, extra={})             # written, then torn
    acp.wait()
    torn = ckpt.latest(str(tmp_path))
    assert torn.endswith("step_00000002")
    assert not ckpt.valid_checkpoint(torn)
    assert ckpt.latest_valid(str(tmp_path)).endswith("step_00000001")


# ---------------------------------------------------------------------------
# End to end: an injected run recovers bit-identical to a clean one
# ---------------------------------------------------------------------------

def test_injected_run_recovers_bit_exact(tiny_model, make_pz,
                                         make_pipeline):
    """dispatch dies once and a prefetch worker dies once; the run retries
    /degrades and still lands on the clean run's exact trajectory, with
    the recoveries visible in RunResult.retry_attempts."""
    pz = make_pz(rounds=6)
    clean = fedsim.run(tiny_model, pz, make_pipeline(), rounds=6,
                       engine="scan", chunk_rounds=2)
    assert clean.retry_attempts == {}
    injector = inj.FaultInjector.from_specs(
        ["dispatch:exception:@1", "chunk_prep:exception:@1"])
    res = fedsim.run(tiny_model, pz, make_pipeline(), rounds=6,
                     engine="scan", chunk_rounds=2, injector=injector)
    assert res.losses == clean.losses
    assert res.p_hats == clean.p_hats
    assert res.retry_attempts == {"dispatch": 1, "prefetch_degraded": 1}
