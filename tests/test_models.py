"""Per-architecture smoke tests: reduced same-family configs on CPU.

Every assigned arch instantiates a REDUCED config (small width/depth/experts)
and runs one forward/train step asserting output shapes + no NaNs; serve
paths (prefill + one decode step) are exercised per family. The FULL configs
are exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS
from repro.models import registry, layers as L

K, B, S = 2, 2, 32
ALL_ARCHS = ASSIGNED_ARCHS + ("opt-125m",)


def _batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (K, B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (K, B, S), 0, cfg.vocab_size),
        "mask": jnp.ones((K, B, S), jnp.float32),
    }
    if cfg.frontend.kind != "none":
        batch["prefix_embeds"] = 0.1 * jax.random.normal(
            key, (K, B, cfg.frontend.n_frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_train_step(arch):
    cfg = registry.get_arch(arch).reduced()
    mod = registry.get_module(cfg)
    params = registry.init_params(jax.random.key(0), cfg, jnp.float32)
    batch = _batch(cfg, jax.random.key(1))
    loss = jax.jit(lambda p, b: mod.loss_per_client(p, cfg, b))(params,
                                                                batch)
    assert loss.shape == (K,)
    assert np.isfinite(np.asarray(loss)).all()
    # plausible initial loss ≈ uniform over the reduced vocab
    assert abs(float(loss.mean()) - np.log(cfg.vocab_size)) < 1.5


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_prefill_decode(arch):
    cfg = registry.get_arch(arch).reduced()
    mod = registry.get_module(cfg)
    params = registry.init_params(jax.random.key(0), cfg, jnp.float32)
    tokens = jax.random.randint(jax.random.key(2), (B, S), 0,
                                cfg.vocab_size)
    if cfg.family == "audio":
        frames = 0.1 * jax.random.normal(
            jax.random.key(3), (B, cfg.frontend.n_frontend_tokens,
                                cfg.d_model))
        logits, cache = mod.prefill(params, cfg, tokens, frames)
    elif cfg.family == "vlm":
        prefix = 0.1 * jax.random.normal(
            jax.random.key(3), (B, cfg.frontend.n_frontend_tokens,
                                cfg.d_model))
        logits, cache = mod.prefill(params, cfg, tokens,
                                    prefix_embeds=prefix)
    else:
        logits, cache = mod.prefill(params, cfg, tokens)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    assert np.isfinite(np.asarray(logits)).all()

    if cfg.family in ("ssm",):
        lg2, _ = mod.decode_step(params, cfg, cache, tokens[:, -1:])
        assert np.isfinite(np.asarray(lg2)).all()
    elif cfg.family == "hybrid":
        lg2, _ = mod.decode_step(params, cfg, cache, tokens[:, -1:],
                                 jnp.int32(S))
        assert np.isfinite(np.asarray(lg2)).all()


def test_decode_matches_forward_dense():
    """Stepwise decode logits == teacher-forced forward logits (yi-family)."""
    cfg = registry.get_arch("yi-6b").reduced()
    mod = registry.get_module(cfg)
    params = registry.init_params(jax.random.key(0), cfg, jnp.float32)
    tokens = jax.random.randint(jax.random.key(1), (1, 12), 0,
                                cfg.vocab_size)
    x = mod.forward(params, cfg, tokens)
    ref_logits = mod.logits_from_hidden(params, x)        # [1, 12, V]
    cache = mod.init_cache(cfg, 1, 12, dtype=jnp.float32)
    outs = []
    for t in range(12):
        lg, cache = mod.decode_step(params, cfg, cache, tokens[:, t:t + 1],
                                    jnp.int32(t))
        outs.append(lg[:, 0])
    step_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(ref_logits), atol=2e-3, rtol=2e-3)


def test_decode_matches_forward_mla():
    """Absorbed-MLA decode equals the expanded teacher-forced path."""
    cfg = registry.get_arch("minicpm3-4b").reduced()
    mod = registry.get_module(cfg)
    params = registry.init_params(jax.random.key(0), cfg, jnp.float32)
    tokens = jax.random.randint(jax.random.key(1), (1, 10), 0,
                                cfg.vocab_size)
    x = mod.forward(params, cfg, tokens)
    ref_logits = mod.logits_from_hidden(params, x)
    cache = mod.init_cache(cfg, 1, 10, dtype=jnp.float32)
    outs = []
    for t in range(10):
        lg, cache = mod.decode_step(params, cfg, cache, tokens[:, t:t + 1],
                                    jnp.int32(t))
        outs.append(lg[:, 0])
    step_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(ref_logits), atol=2e-3, rtol=2e-3)


def test_ssm_decode_matches_forward():
    cfg = registry.get_arch("mamba2-370m").reduced()
    mod = registry.get_module(cfg)
    params = registry.init_params(jax.random.key(0), cfg, jnp.float32)
    tokens = jax.random.randint(jax.random.key(1), (1, 12), 0,
                                cfg.vocab_size)
    x = mod.forward(params, cfg, tokens)
    ref_logits = L.unembed(params.get("lm_head", params["embed"]),
                           x)
    state = mod.init_state(cfg, 1, dtype=jnp.float32)
    outs = []
    for t in range(12):
        lg, state = mod.decode_step(params, cfg, state, tokens[:, t:t + 1])
        outs.append(lg[:, 0])
    step_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(ref_logits), atol=2e-3, rtol=2e-3)


def test_cross_entropy_matches_naive():
    key = jax.random.key(0)
    logits = jax.random.normal(key, (4, 8, 32))
    targets = jax.random.randint(jax.random.key(1), (4, 8), 0, 32)
    mask = (jax.random.uniform(jax.random.key(2), (4, 8)) > 0.3
            ).astype(jnp.float32)
    got = L.cross_entropy(logits, targets, mask)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    want = jnp.sum((lse - tgt) * mask, -1) / jnp.maximum(mask.sum(-1), 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_param_counts_match_published_sizes():
    expected = {
        "deepseek-v2-236b": (239e9, 0.03),
        "yi-6b": (6.06e9, 0.02),
        "deepseek-coder-33b": (33.3e9, 0.02),
        "mamba2-370m": (0.37e9, 0.03),
        "minicpm3-4b": (4.3e9, 0.03),
    }
    for arch, (want, tol) in expected.items():
        got = registry.count_params(registry.get_arch(arch))
        assert abs(got - want) / want < tol, (arch, got, want)


def test_moe_capacity_exactness():
    """With generous capacity, grouped MoE equals dense expert mixture."""
    import dataclasses
    from repro.configs.base import MoEConfig
    cfg = registry.get_arch("moonshot-v1-16b-a3b").reduced()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0, chunk=0))
    params = L.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model)) * 0.5
    got = L.moe(params, x, cfg)

    # dense reference: every expert computes everything, gated combine
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    k = cfg.moe.n_experts_per_tok
    gates, idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(gates, axis=-1)
    hi = jnp.einsum("bsd,edf->bsef", x, params["we_i"])
    hg = jnp.einsum("bsd,edf->bsef", x, params["we_g"])
    h = jax.nn.silu(hg) * hi
    ye = jnp.einsum("bsef,efd->bsed", h, params["we_d"])
    sel = jnp.take_along_axis(ye, idx[..., None], axis=2)
    want = jnp.sum(sel * gates[..., None], axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-3)
