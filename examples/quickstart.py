"""Quickstart: 5 clients fine-tune a tiny LM with pAirZero in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py

What happens:
  * 5 simulated clients each hold a private shard of a synthetic SST-2-like
    task;
  * every round the server broadcasts a seed; clients run TWO forward passes
    (w ± μz, z regenerated from the seed — no gradients, no activation
    memory) and transmit ONE scalar each over a simulated wireless channel;
  * signals superpose in the air; the server recovers the noisy mean by
    channel inversion and everyone applies w ← w − η·p̂·z;
  * transmit power follows the paper's Theorem-3 schedule, so the whole run
    is (ε=5, δ=0.01)-differentially private — by channel noise alone.
"""
import sys

sys.path.insert(0, "src")

from repro.configs.base import (ChannelConfig, DPConfig, ModelConfig,
                                PairZeroConfig, TransportConfig, ZOConfig)
from repro.core import fedsim
from repro.data.pipeline import FederatedPipeline
from repro.data.tasks import TaskSpec


def main() -> None:
    model = ModelConfig(name="quickstart-lm", family="dense", n_layers=2,
                        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                        vocab_size=64, head_dim=16)

    pairzero = PairZeroConfig(
        n_clients=5,
        zo=ZOConfig(mu=1e-3, lr=5e-3, clip_gamma=5.0, n_perturb=4),
        channel=ChannelConfig(n0=1.0, power=1000.0),
        dp=DPConfig(epsilon=5.0, delta=0.01),
        # the uplink mechanism, from the transport registry: "perfect" is
        # the noise-free upper bound; try "analog"/"sign" for the OTA
        # mechanisms or "digital" for the conventional quantized baseline
        transport=TransportConfig(mechanism="perfect"),
    )

    data = FederatedPipeline(task="sst2",
                             spec=TaskSpec("sst2", 64, 24),
                             n_clients=5, per_client_batch=8, seed=0)

    print("== pAirZero quickstart: 600 rounds, 5 clients ==")
    result = fedsim.run(
        model, pairzero, data, rounds=600, eval_every=150, eval_n=256,
        on_round=lambda t, m: t % 100 == 0 and print(
            f"  round {t:4d}  loss {m['loss']:.3f}"))

    print(f"\naccuracy trajectory: {[round(a, 2) for a in result.accuracies]}")
    print(f"total uplink, all clients: {result.uplink_bits / 8:.0f} bytes "
          f"({result.steps} rounds x 4 perturbations x fp16 scalar x 5)")
    print(f"an FO baseline would have uploaded "
          f"{result.steps * model.param_count() * 2 / 1e6:.1f} MB per client")


if __name__ == "__main__":
    main()
