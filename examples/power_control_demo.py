"""Power-control deep dive: Theorems 3 & 4 schedules, visualized as CSV.

    PYTHONPATH=src python examples/power_control_demo.py [--rounds 2000]

Draws a Rayleigh block-fading channel trace for K clients, solves the
optimality-gap minimization (Theorem 3 analog / Theorem 4 sign), and prints
per-round schedules for Solution / Static / Reversed side by side, plus the
privacy ledger showing each scheme exhausts (or wastes) the (ε, δ) budget.
Writes results/power_schedules.csv for plotting.
"""
import argparse
import os
import sys

sys.path.insert(0, "src")

import numpy as np

from repro import channel
from repro.configs.base import (ChannelConfig, DPConfig, PairZeroConfig,
                                ZOConfig)
from repro.core import dp
from repro.core import transport as tp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=2000)
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--power", type=float, default=100.0)
    ap.add_argument("--epsilon", type=float, default=5.0)
    ap.add_argument("--delta", type=float, default=0.01)
    args = ap.parse_args()

    trace = channel.RayleighFading().realize(0, args.rounds,
                                             args.clients)
    budget = dp.r_dp(args.epsilon, args.delta)
    print(f"R_dp(ε={args.epsilon}, δ={args.delta}) = {budget:.4f}")

    # schedules come from the Transport protocol: each mechanism owns its
    # host-side solve (Theorem 3 for analog, Theorem 4 for sign)
    pz = PairZeroConfig(
        n_clients=args.clients, rounds=args.rounds,
        zo=ZOConfig(clip_gamma=100.0),
        channel=ChannelConfig(n0=1.0, power=args.power),
        dp=DPConfig(epsilon=args.epsilon, delta=args.delta))
    schedules = {
        "solution": tp.AnalogOTA(scheme="solution").make_schedule(trace, pz),
        "static": tp.AnalogOTA(scheme="static").make_schedule(trace, pz),
        "reversed": tp.AnalogOTA(scheme="reversed").make_schedule(trace, pz),
        "sign_solution": tp.SignOTA(scheme="solution").make_schedule(trace, pz),
    }

    print(f"\n{'scheme':14s} {'c(1)':>10s} {'c(T/2)':>10s} {'c(T)':>10s} "
          f"{'privacy spent':>14s} {'of budget':>10s}")
    for name, s in schedules.items():
        gamma = 1.0 if name.startswith("sign") else 100.0
        spent = s.privacy_cost(np.full(args.rounds, gamma))
        print(f"{name:14s} {s.c[0]:10.3e} {s.c[args.rounds // 2]:10.3e} "
              f"{s.c[-1]:10.3e} {spent:14.4f} {spent / budget:9.1%}")

    print("\ninterpretation:")
    print("  * solution: c(t) grows like A^{-t/4} — later rounds transmit")
    print("    cleaner (the convergence bound weights late noise A^{-t});")
    print("  * static: constant c — for large T it collapses toward zero")
    print("    (the Fig. 3 failure mode);")
    print("  * reversed: decays — provably worse weighting;")
    print("  * all schemes stop exactly at the privacy budget.")

    os.makedirs("results", exist_ok=True)
    with open("results/power_schedules.csv", "w") as f:
        f.write("t," + ",".join(schedules) + ",h_min\n")
        for t in range(args.rounds):
            f.write(f"{t}," + ",".join(f"{s.c[t]:.6e}"
                                       for s in schedules.values())
                    + f",{trace.h[t].min():.4f}\n")
    print("\nwrote results/power_schedules.csv")


if __name__ == "__main__":
    main()
