"""Serve a (fine-tuned) model with batched requests — any --arch.

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-370m
    PYTHONPATH=src python examples/serve_batched.py --arch yi-6b --gen 24

Uses the reduced config on CPU; the identical decode_step is what the
decode_32k / long_500k dry-run cells lower at production shapes. Requests of
different prompt lengths are left-padded into one batch (continuous batching
is a scheduler concern; the step itself is batch-first).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import serve_loop
from repro.models import registry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = registry.get_arch(args.arch).reduced()
    params = registry.init_params(jax.random.key(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)

    # a "request queue": variable-length prompts left-padded to one batch
    lens = rng.integers(args.prompt_len // 2, args.prompt_len + 1,
                        size=args.batch)
    batch = np.zeros((args.batch, args.prompt_len), np.int32)
    for i, ln in enumerate(lens):
        batch[i, -ln:] = rng.integers(8, cfg.vocab_size, size=ln)

    print(f"serving {args.arch} (reduced): batch={args.batch} "
          f"prompts of lens {lens.tolist()}")
    t0 = time.time()
    out = serve_loop(cfg, params, batch, args.gen)
    dt = time.time() - t0
    print(f"generated {args.gen} tokens/req in {dt:.2f}s "
          f"({args.gen * args.batch / dt:.1f} tok/s on CPU)")
    for i in range(min(args.batch, 2)):
        print(f"  req{i}: ...{out[i, -args.gen:].tolist()}")


if __name__ == "__main__":
    main()
