"""End-to-end driver: federated DP fine-tuning with faults + checkpointing.

    # fast preset (default, ~2 min on CPU):
    PYTHONPATH=src python examples/federated_finetune.py

    # the paper's own model (OPT-125M, ~125M params — slow on CPU;
    # a few hundred steps as the deliverable prescribes):
    PYTHONPATH=src python examples/federated_finetune.py \
        --preset opt125m --rounds 300

Demonstrates the full production path: Theorem-3 power control under a
Rayleigh block-fading channel, the (ε, δ) privacy accountant, client dropout
+ stragglers, elastic membership, crash-safe checkpointing, and resume.
"""
import argparse
import os
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs.base import (ChannelConfig, DPConfig, ModelConfig,
                                PairZeroConfig, TransportConfig, ZOConfig)
from repro.core import fedsim
from repro.data.pipeline import FederatedPipeline
from repro.data.tasks import TaskSpec
from repro.models import registry
from repro.runtime.fault import ElasticSchedule, FaultModel

PRESETS = {
    "tiny": dict(arch=None, rounds=600, lr=2e-3, seq=24, batch=8),
    "small": dict(arch=None, rounds=400, lr=5e-3, seq=32, batch=8),
    "opt125m": dict(arch="opt-125m", rounds=300, lr=5e-7, seq=64, batch=4),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--transport", default="analog",
                    choices=["analog", "sign", "digital"],
                    help="uplink mechanism (see repro.core.transport); "
                         "'digital' is the conventional quantized baseline")
    ap.add_argument("--epsilon", type=float, default=None,
                    help="DP ε (default: 50 for the fast presets — the "
                         "paper's ε=5 needs its T=8000 horizon to exit the "
                         "noise floor; opt125m preset defaults to ε=5)")
    ap.add_argument("--engine", default="loop", choices=["loop", "scan"],
                    help="round executor; 'scan' batches --chunk-rounds "
                         "rounds per device dispatch (fastest for long runs)")
    ap.add_argument("--chunk-rounds", type=int, default=16)
    ap.add_argument("--ckpt", default="/tmp/pairzero_ckpt")
    args = ap.parse_args()
    p = PRESETS[args.preset]
    rounds = args.rounds or p["rounds"]

    if p["arch"]:
        model = registry.get_arch(p["arch"])
        gamma = 100.0               # paper's γ for OPT-125M
    else:
        width = 64 if args.preset == "tiny" else 128
        model = ModelConfig(name=f"{args.preset}-lm", family="dense",
                            n_layers=2 if args.preset == "tiny" else 4,
                            d_model=width, n_heads=4, n_kv_heads=2,
                            d_ff=2 * width, vocab_size=64, head_dim=16)
        gamma = 5.0

    eps = args.epsilon if args.epsilon is not None else (
        5.0 if args.preset == "opt125m" else 50.0)
    pz = PairZeroConfig(
        n_clients=5, rounds=rounds,
        zo=ZOConfig(mu=1e-3, lr=p["lr"], clip_gamma=gamma, n_perturb=4),
        channel=ChannelConfig(n0=1.0, power=100.0,
                              d=model.param_count()),
        # the digital baseline has no DP mechanism (orthogonal decoding
        # exposes each payload) — run it openly non-private
        dp=DPConfig(epsilon=eps, delta=0.01,
                    enabled=args.transport != "digital"),
        transport=TransportConfig(mechanism=args.transport,
                                  scheme="solution"))

    data = FederatedPipeline(task="sst2",
                             spec=TaskSpec("sst2", model.vocab_size,
                                           p["seq"]),
                             n_clients=5, per_client_batch=p["batch"],
                             seed=0)

    # 5% transient dropout + occasional stragglers; client 4 leaves at 60%
    # of the run and returns at 80% (elastic membership)
    fault = FaultModel(n_clients=5, dropout_p=0.05, straggler_p=0.02,
                       seed=1)
    elastic = ElasticSchedule(n_clients=5, events=(
        (int(rounds * 0.6), 4), (int(rounds * 0.8), 5)))

    print(f"== federated fine-tune: {model.name} "
          f"({model.param_count() / 1e6:.1f}M params), {args.transport}, "
          f"Theorem-3 power control, ε={eps:g}, {rounds} rounds ==")
    res = fedsim.run(
        model, pz, data, rounds=rounds,
        engine=args.engine, chunk_rounds=args.chunk_rounds,
        eval_every=max(rounds // 4, 1), eval_n=256,
        checkpoint_dir=args.ckpt, checkpoint_every=max(rounds // 3, 1),
        fault=fault, elastic=elastic,
        on_round=lambda t, m: t % max(rounds // 10, 1) == 0 and print(
            f"  round {t:5d}  loss {m['loss']:.4f}  K_eff "
            f"{int(m.get('k_eff', 5))}"))

    print(f"\nfinal loss     : {np.mean(res.losses[-10:]):.4f} "
          f"(start {np.mean(res.losses[:5]):.4f})")
    if res.accuracies:
        print(f"accuracies     : {[round(a, 2) for a in res.accuracies]}")
    if args.transport == "digital":
        print("privacy        : NONE — digital orthogonal uplink exposes "
              "each client's payload (the trilemma's third corner)")
    else:
        print(f"privacy        : spent {res.privacy_spent:.4f} of "
              f"{res.privacy_budget:.4f}  (ε={eps:g}, δ=0.01)")
    print(f"uplink         : {res.uplink_bits / 8e6:.3f} MB total over "
          f"{res.steps} rounds ({args.transport} transport)")
    print(f"checkpoints in : {args.ckpt} (re-run to resume from "
          f"round {res.steps + res.resumed_from})")


if __name__ == "__main__":
    main()
